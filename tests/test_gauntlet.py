"""Miniaturized gauntlet in the fast suite.

Runs a subset of the registered scenario families end-to-end — generator →
virtual-time drive → summarize → SLO grade — on the tiny model, asserting
the rows/grades the bench suite and CI gate depend on: SLO-grade rows are
produced with per-class TTFT percentiles, the aging bound holds under the
starvation scenario, hot-swap storms drop nothing, telemetry JSONL is
written, and greedy outputs under loadgen-driven bursty arrivals stay
bit-identical to the static oracle (the differential harness's new arrival
axis, pinned here explicitly)."""
import dataclasses
import json
import os

import numpy as np
import jax
import pytest

import benchmarks.gauntlet as G
from repro.engine import loadgen as lg
from repro.engine.serve import ServeEngine

from conftest import PYTEST_SEED
from test_serve_differential import gen_scenario, oracle, run_scenario


# fast-suite subset: baseline + the two adversarial families whose grades
# are load-bearing (starvation exercises the aging bound, the storm
# exercises hot-swap safety); the full registry runs in the bench job
FAST_SCENARIOS = ("steady_poisson", "priority_starvation",
                  "hot_swap_storm")


@pytest.mark.parametrize("name", FAST_SCENARIOS)
def test_scenario_produces_graded_row(name):
    (row_name, us, derived), metrics, ok, detail = \
        G.run_scenario(name, smoke=True)
    assert row_name == f"gauntlet/{name}"
    assert us > 0
    assert derived.startswith("slo=")
    assert ok, f"{name} SLO grade failed: {detail}"
    # the row schema the CI gate greps: grade + the headline metrics
    for key in ("p50_ttft=", "p99_ttft=", "goodput=", "max_deferred=",
                "dropped="):
        assert key in derived, derived
    assert metrics["dropped"] == 0, "the engine never sheds load"
    assert metrics["completed"] == metrics["n"]


def test_starvation_scenario_aging_bound_holds():
    """Under the interactive flood, batch prefills age but the per-class
    bound caps how long: max_deferred stays within the generous SLO bound
    AND the hard engine guarantee (an aged prefill preempts, so the peak
    can only exceed max_defer by the overshoot of one arbitration round)."""
    _, metrics, ok, detail = G.run_scenario("priority_starvation",
                                            smoke=True)
    assert ok, detail
    assert "batch/p50_ttft" in metrics and "interactive/p50_ttft" in metrics
    bound = dict((c.name, c.max_defer) for c in G._STARVE_CLASSES)["batch"]
    assert metrics["batch/max_deferred"] <= bound + 4, metrics
    assert metrics["batch/dropped"] == 0


def test_hot_swap_storm_applies_events_and_drops_nothing():
    spec = G._mini(lg.SCENARIOS["hot_swap_storm"])
    eng = G._engine_for("hot_swap_storm")
    res = lg.drive(eng, lg.generate(spec, PYTEST_SEED), max_ticks=20_000,
                   events=spec.event_list())
    assert res.events_applied >= 2, "storm events must actually land"
    assert eng.params_version >= 1000, "version bumps must apply"
    m = lg.summarize(res)
    assert m["dropped"] == 0 and m["completed"] == m["n"]


def test_telemetry_jsonl_written(tmp_path, monkeypatch):
    monkeypatch.setenv("GAUNTLET_TELEMETRY_DIR", str(tmp_path))
    G.run_scenario("steady_poisson", smoke=True)
    path = tmp_path / "steady_poisson.jsonl"
    assert path.exists()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) >= 2
    assert all("decision" in l for l in lines[:-1]), \
        "body lines are decision records"
    tail = lines[-1]
    assert tail["summary"] == "steady_poisson"
    assert "p99_ttft" in tail["metrics"] and "slo_pass" in tail
    assert "knobs" in tail and "spec_len" in tail["knobs"]


def test_drive_virtual_time_fast_forwards_idle():
    """Sparse arrivals must not burn one engine tick per empty virtual
    tick: the harness fast-forwards the clock to the next arrival."""
    eng = G._engine_for("steady_poisson")
    reqs = [lg.GenRequest(at=0, prompt=(5, 6, 7), max_new=2),
            lg.GenRequest(at=500, prompt=(8, 9, 10), max_new=2)]
    res = lg.drive(eng, reqs, max_ticks=5000)
    assert res.idle_skipped > 400, res
    assert res.ticks - res.idle_skipped < 60, \
        "busy ticks must stay near the actual work"
    assert all(tr.t_done is not None for tr in res.traces)


def test_drive_replay_identical_streams():
    """Same scenario+seed driven twice on fresh engines: identical request
    streams AND identical greedy outputs (the engine decisions may differ
    — they are wall-clock-EMA driven — but results may not)."""
    spec = dataclasses.replace(G._mini(lg.SCENARIOS["bursty_overload"]),
                               n=6)
    outs = []
    for _ in range(2):
        eng = G._engine_for("bursty_overload")
        res = lg.drive(eng, lg.generate(spec, PYTEST_SEED),
                       max_ticks=20_000)
        outs.append([tr.req.output().tolist() for tr in res.traces])
    assert outs[0] == outs[1]


def test_bursty_load_bit_identical_to_oracle():
    """The tentpole invariant under the new arrival axis: a bursty loadgen
    arrival pattern driven through the differential harness must keep
    greedy outputs bit-identical to ``generate_static``."""
    rng = np.random.default_rng(PYTEST_SEED + 31337)
    sc = gen_scenario(rng)
    at = lg.arrival_offsets("bursty", len(sc["prompts"]), rng, burst=2,
                            gap=4.0)
    sc["arrival"] = [int(t) for t in np.minimum(at, 12)]
    sc["spec"] = True
    run_scenario(sc)     # asserts outputs == oracle internally


def test_differential_arrival_axis_samples():
    """A few extra seeded differential cases pinned to non-closed
    arrivals, so the axis is exercised every run regardless of what the
    shared sweep draws."""
    for case in range(2):
        rng = np.random.default_rng(PYTEST_SEED * 7919 + case)
        sc = gen_scenario(rng)
        while sc.get("arrival") is None:
            sc = gen_scenario(rng)
        run_scenario(sc)
