"""Device-placed slot pools: placement scheduling units, elastic
add/drain under load, and live slot migration across meshes.

The invariant everywhere is the serving layer's one contract: placement
and migration may only ever RELOCATE work — greedy outputs must stay
bit-identical to the static ``BatchedServer.generate_static`` oracle
whatever the pool meshes, however many times a slot moved mid-stream.
A slot's pool row + position + PRNG key fully determine its continuation,
so a migrated request's remaining tokens must match an unmigrated run's
exactly, across every cache family the repo carries (KV attention /
recurrent / SSM-hybrid), with draft rows and n-gram tables riding along.

Runs on ONE device (conftest strips XLA_FLAGS): placements degrade to
same-device meshes, which still exercise the placed code paths —
committed params/caches, per-placement jit specializations, the
gather/put/scatter migration transfer.  The CI multidevice job re-runs
this file under ``--xla_force_host_platform_device_count=8`` (with
``REPRO_MULTIDEVICE=1``) so disjoint device groups and the parallel
group-tick path run for real.
"""
from functools import lru_cache

import numpy as np
import jax
import pytest

from repro.configs import get_arch
from repro.engine import Engine, ServeEngine
from repro.models import lm
from repro.runtime.serve import BatchedServer
from repro.runtime.sharding import axis_size, pool_mesh, pool_specs

MAX_LEN = 64
# the three cache families a pool row can carry: KV attention rows
# (gemma3), pure recurrent state (rwkv6), SSM+attention hybrid (zamba2)
FAMS = ["gemma3-1b", "rwkv6-1.6b", "zamba2-7b"]


@lru_cache(maxsize=None)
def _fixture(arch="gemma3-1b"):
    cfg = get_arch(arch + "-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, params, BatchedServer(cfg, params, max_len=MAX_LEN)


_ORACLE = {}


def oracle(arch, prompt, max_new):
    key = (arch, tuple(int(t) for t in prompt), int(max_new))
    if key not in _ORACLE:
        _, _, srv = _fixture(arch)
        _ORACLE[key] = srv.generate_static(
            np.asarray(prompt, np.int32)[None], max_new=int(max_new))[0]
    return _ORACLE[key]


def _halves():
    """Two pool placements: disjoint halves on a multi-device host,
    same-device meshes on one."""
    devs = jax.devices()
    half = max(len(devs) // 2, 1)
    return {0: devs[:half], 1: devs[half:] or devs}


def _prompts(n, seed=0, vocab=100):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(4, 14))).tolist()
            for _ in range(n)]


def _run(eng, reqs, drain_at=None, add_at=None, add_kw=None, max_ticks=600):
    """Drive to completion with optional mid-stream drain/join events."""
    for t in range(max_ticks):
        if t == drain_at and len(eng.pools) > 1:
            eng.drain_pool(eng.pools[0].lid)
        if t == add_at:
            eng.add_pool(**(add_kw or {}))
        if not eng.tick():
            break
        if all(len(r.tokens) >= r.max_new for r in reqs):
            return
    assert all(len(r.tokens) >= r.max_new for r in reqs), \
        "requests did not finish"


def _assert_oracle(arch, prompts, max_new, reqs):
    for i, (p, r) in enumerate(zip(prompts, reqs)):
        np.testing.assert_array_equal(
            r.output(), oracle(arch, p, max_new),
            err_msg=f"req {i} plen={len(p)}")


# ----------------------------------------------------------- sharding units

def test_pool_mesh_axes():
    devs = jax.devices()
    m = pool_mesh(devs[:1])
    assert tuple(m.axis_names) == ("data", "model")
    assert axis_size(m, "data") == 1 and axis_size(m, "model") == 1
    if len(devs) >= 2:
        m2 = pool_mesh(devs[:2])
        assert axis_size(m2, "data") == 2
        m2t = pool_mesh(devs[:2], tp=2)
        assert axis_size(m2t, "model") == 2


def test_pool_specs_slot_dim_divisibility():
    """Slot-dim sharding only when the leading dim divides the data axis;
    otherwise the leaf replicates (placement must accept ANY slot count)."""
    def slot_sharded(spec):
        return spec[0] is not None and "data" in tuple(jax.tree.leaves(
            (spec[0],)))

    m = pool_mesh(jax.devices()[:1])
    tree = {"a": np.zeros((4, 3)), "b": np.zeros((3, 2))}
    specs = pool_specs(m, tree)
    assert slot_sharded(specs["a"]) and slot_sharded(specs["b"])
    if len(jax.devices()) >= 2:
        m2 = pool_mesh(jax.devices()[:2])
        specs2 = pool_specs(m2, tree)
        assert slot_sharded(specs2["a"])
        # 3 slots don't divide 2 devices -> replicated
        assert specs2["b"][0] is None


# --------------------------------------------------------- scheduling units

def test_placement_adjusted_frt_reduces_to_weighted():
    from repro.core.scheduler import placement_adjusted_frt
    assert placement_adjusted_frt(2.0, 4.0) == \
        placement_adjusted_frt(2.0, 4.0, load=0.0, xfer=0.0) == 0.5
    assert placement_adjusted_frt(2.0, 1.0, load=1.0) == 4.0
    assert placement_adjusted_frt(2.0, 1.0, xfer=3.0) == 5.0


def test_choose_admission_pool_prefers_idle_device_group():
    eng = Engine()
    got = eng.choose_admission_pool([
        {"pool": 0, "free": 1, "busy": 0.9, "devices": 1},
        {"pool": 1, "free": 1, "busy": 0.0, "devices": 1}])
    assert got == 1
    assert eng.decisions[-1]["decision"] == "admission_pool"


def test_choose_migration_dst_prefers_free_capacity():
    eng = Engine()
    got = eng.choose_migration_dst([
        {"pool": 1, "free": 1, "busy": 0.0, "devices": 1},
        {"pool": 2, "free": 4, "busy": 0.0, "devices": 1}])
    assert got == 2
    assert eng.decisions[-1]["decision"] == "migration_dst"


# -------------------------------------------- placed serving bit-identity

def test_placed_pools_match_oracle_and_unplaced():
    arch = "gemma3-1b"
    cfg, params, _ = _fixture(arch)
    prompts = _prompts(4, seed=1)
    placed = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2, pools=2,
                         prefill_chunk=4, decode_chunk=2,
                         placements=_halves())
    plain = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2, pools=2,
                        prefill_chunk=4, decode_chunk=2)
    rp = [placed.submit(p, max_new=10) for p in prompts]
    ru = [plain.submit(p, max_new=10) for p in prompts]
    _run(placed, rp)
    _run(plain, ru)
    _assert_oracle(arch, prompts, 10, rp)
    for a, b in zip(rp, ru):
        np.testing.assert_array_equal(a.output(), b.output())
    ins = placed._inspect("status")["placement"]
    assert ins["placed_pools"] == 2


@pytest.mark.parametrize("arch", FAMS)
def test_migration_roundtrip_bit_identical(arch):
    """Mid-stream migration per cache family: pin 2 requests to pool 0,
    let them emit a few tokens, drain pool 0 into pool 1's free slots,
    and require the continuations to match the never-migrated oracle."""
    cfg, params, _ = _fixture(arch)
    prompts = _prompts(2, seed=2, vocab=min(cfg.vocab, 100))
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=4, pools=2,
                      prefill_chunk=4, decode_chunk=2,
                      placements=_halves())
    reqs = [eng.submit(p, max_new=12, pool=0) for p in prompts]
    # run into decode (some tokens out) before draining: a true mid-stream
    # move, prompt consumed + generated tokens in the cache rows
    t = 0
    while not any(r.tokens for r in reqs):
        assert eng.tick() and t < 200
        t += 1
    eng.drain_pool(0)
    _run(eng, reqs)
    assert eng.migrated_slots >= 1, "drain finished without migrating"
    assert [sp.lid for sp in eng.pools] == [1]
    _assert_oracle(arch, prompts, 12, reqs)


@pytest.mark.parametrize("extra", [{"draft": "self"}, {"spec_decode": True}])
def test_migration_carries_proposer_state(extra):
    """Draft-model rows and n-gram tables live inside the pool pytree, so
    they migrate with the slot; speculative outputs must stay exact."""
    arch = "gemma3-1b"
    cfg, params, _ = _fixture(arch)
    prompts = _prompts(2, seed=3)
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=4, pools=2,
                      prefill_chunk=4, decode_chunk=2,
                      placements=_halves(), **extra)
    reqs = [eng.submit(p, max_new=12, pool=0) for p in prompts]
    t = 0
    while not any(r.tokens for r in reqs):
        assert eng.tick() and t < 200
        t += 1
    eng.drain_pool(0)
    _run(eng, reqs)
    assert eng.migrated_slots >= 1
    _assert_oracle(arch, prompts, 12, reqs)


def test_drain_under_load_zero_dropped():
    """Saturated fleet + queue backlog, drain mid-run: every request —
    in-flight, queued, pinned or not — completes with oracle-exact
    output; nothing is dropped and nothing re-runs from scratch into a
    different answer."""
    arch = "gemma3-1b"
    cfg, params, _ = _fixture(arch)
    prompts = _prompts(7, seed=4)
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2, pools=2,
                      prefill_chunk=4, decode_chunk=2,
                      placements=_halves())
    reqs = [eng.submit(p, max_new=8) for p in prompts]
    _run(eng, reqs, drain_at=3)
    assert len(eng.pools) == 1
    assert not eng.queue
    _assert_oracle(arch, prompts, 8, reqs)


def test_join_while_saturated():
    """Elastic scale-out under backlog: a pool added mid-run absorbs
    queued work (its slots actually serve) without disturbing a single
    in-flight output."""
    arch = "gemma3-1b"
    cfg, params, _ = _fixture(arch)
    prompts = _prompts(6, seed=5)
    devs = jax.devices()
    half = max(len(devs) // 2, 1)
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2, pools=1,
                      prefill_chunk=4, decode_chunk=2,
                      placements={0: devs[:half]})
    reqs = [eng.submit(p, max_new=8) for p in prompts]
    served_pools = set()
    for t in range(600):
        if t == 2:
            lid = eng.add_pool(placement=devs[half:] or devs, slots=2)
            assert lid == 1
        assert eng.tick()
        served_pools.update(r.pool for r in reqs if r.pool >= 0)
        if all(len(r.tokens) >= r.max_new for r in reqs):
            break
    assert all(len(r.tokens) >= r.max_new for r in reqs)
    assert len(eng.pools) == 2
    assert eng.pools[1].lid == 1 and eng.pools[1].mesh is not None
    assert 1 in served_pools, "joined pool never served a request"
    _assert_oracle(arch, prompts, 8, reqs)


def test_drain_rejects_last_pool():
    cfg, params, _ = _fixture()
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2, pools=1,
                      prefill_chunk=4, decode_chunk=2)
    with pytest.raises(AssertionError):
        eng.drain_pool(0)


def test_prefix_snapshots_are_host_numpy():
    """Satellite invariant: every prefix-cache snapshot leaf is host
    numpy — placement-portable (seeds any pool's mesh) and it survives
    the capturing pool being drained away."""
    cfg, params, _ = _fixture()
    prompts = [[7] * 8 + [i + 1] for i in range(3)]
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2, pools=2,
                      prefill_chunk=4, decode_chunk=2, prefix_cache=True,
                      placements=_halves())
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    _run(eng, reqs)
    snaps = []

    def walk(node):
        if node.snapshot is not None:
            snaps.append(node.snapshot)
        for c in node.children.values():
            walk(c)

    walk(eng.prefix.root)
    assert snaps, "no snapshots captured"
    for s in snaps:
        for leaf in jax.tree.leaves(s):
            assert isinstance(leaf, np.ndarray), type(leaf)
    _assert_oracle("gemma3-1b", prompts, 6, reqs)


def test_migration_xfer_term_reaches_candidates():
    """While a drain is pending toward a pool, that pool's tick
    candidates must carry a positive transfer-cost term (the xfer input
    of placement_adjusted_frt)."""
    cfg, params, _ = _fixture()
    prompts = _prompts(4, seed=6)
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2, pools=2,
                      prefill_chunk=4, decode_chunk=2,
                      placements=_halves())
    reqs = [eng.submit(p, max_new=16) for p in prompts]
    # a couple of ticks to get slots occupied in both pools
    for _ in range(3):
        assert eng.tick()
    eng.drain_pool(0)
    # a migration batch has already landed on pool 1 and more slots are
    # still pending in the draining pool — pool 1's candidates must be
    # priced with the positive transfer term
    eng._last_mig_dst = 1
    cands = eng._candidates()
    by_pool = {c.pool_id - eng.pool_id: c for c in cands}
    assert 1 in by_pool and by_pool[1].xfer > 0
    # other pools carry no transfer term
    assert all(c.xfer == 0 for lid, c in by_pool.items() if lid != 1)
    _run(eng, reqs)
    _assert_oracle("gemma3-1b", prompts, 16, reqs)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (CI multidevice job)")
def test_parallel_group_ticks_on_disjoint_devices():
    """With pools on disjoint device groups, scheduling rounds co-dispatch
    decode ticks for the non-winning placed pools."""
    cfg, params, _ = _fixture()
    prompts = _prompts(4, seed=7)
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2, pools=2,
                      prefill_chunk=4, decode_chunk=2,
                      placements=_halves())
    reqs = [eng.submit(p, max_new=10) for p in prompts]
    _run(eng, reqs)
    assert eng.parallel_group_ticks > 0
    _assert_oracle("gemma3-1b", prompts, 10, reqs)
