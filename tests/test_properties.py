"""Property-based tests (hypothesis) for system invariants."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, seed, settings, strategies as st  # noqa: E402

from conftest import PYTEST_SEED  # noqa: E402

from repro.core.estimator import MeanModelEstimator
from repro.core.skew import SkewParams, detect
from repro.core.transfer import PartitionLogic, sbr_apply, sbr_fraction


@seed(PYTEST_SEED)
@settings(max_examples=50, deadline=None)
@given(st.dictionaries(st.integers(0, 15),
                       st.floats(0, 1e6, allow_nan=False), min_size=2),
       st.floats(1, 1e4), st.floats(1, 1e4))
def test_detect_invariants(loads, eta, tau):
    pairs = detect(loads, SkewParams(eta=eta, tau=tau))
    flat = [w for p in pairs for w in p]
    assert len(flat) == len(set(flat))               # no worker reused
    for s, h in pairs:
        assert loads[s] >= eta
        assert loads[s] - loads[h] >= tau            # eq (3.1),(3.2)


@seed(PYTEST_SEED)
@settings(max_examples=50, deadline=None)
@given(st.floats(0.001, 1e6), st.floats(0, 1e6))
def test_sbr_fraction_bounds_and_balance(phi_s, phi_h):
    f = sbr_fraction(phi_s, phi_h)
    assert 0.0 <= f <= 1.0
    if phi_s >= phi_h:
        # after the split both sides receive equal load (up to clipping)
        s_after = phi_s * (1 - f)
        h_after = phi_h + phi_s * f
        if f < 1.0:
            assert abs(s_after - h_after) < 1e-6 * max(phi_s, 1.0)


@seed(PYTEST_SEED)
@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(2, 20),
       st.floats(0.05, 0.95))
def test_partition_logic_route_distribution(n_workers, n_keys, frac):
    logic = PartitionLogic.modulo(list(range(n_keys)), n_workers)
    sbr_apply(logic, 0, 1, frac)
    for k in range(n_keys):
        if logic.assignment[k][-1][0] == 0:          # owned by worker 0
            hits = sum(logic.route(k, (i + 0.5) / 1000.0) == 1
                       for i in range(1000))
            assert abs(hits / 1000.0 - frac) < 0.01


@seed(PYTEST_SEED)
@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(1, 1e4), min_size=2, max_size=50))
def test_estimator_eps_decreases_with_n(xs):
    est = MeanModelEstimator()
    # constant-ish samples: eps shrinks as n grows
    for x in xs:
        est.add({0: 10.0})
    _, eps = est.predict(0)
    assert eps == 0.0 or eps < 1e-9


@seed(PYTEST_SEED)
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 64), st.integers(2, 8), st.integers(1, 4))
def test_dispatch_every_kept_token_appears_once(t, e, k):
    import jax
    import jax.numpy as jnp
    from repro.models.moe import dispatch_combine
    k = min(k, e)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((t, 8)), jnp.float32)
    slot = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    w = jnp.ones((t, k)) / k
    # random slots may repeat within a row (unlike real top-k), so an
    # expert can receive up to t*k assignments — size capacity accordingly
    cap = max(1, t * k)

    def ident(buf):
        return buf                                   # expert = identity

    y, m = dispatch_combine(x, slot, w, ident, e, cap)
    # with identity experts + ample capacity, combine(dispatch(x)) == x
    assert int(m["dropped"]) == 0
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)
    # capacity invariant
    assert int(np.asarray(m["kept_counts"]).max()) <= cap


@seed(PYTEST_SEED)
@settings(max_examples=20, deadline=None)
@given(st.integers(8, 64), st.integers(2, 8))
def test_dispatch_capacity_respected(t, e):
    import jax.numpy as jnp
    from repro.models.moe import dispatch_combine
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((t, 4)), jnp.float32)
    slot = jnp.zeros((t, 1), jnp.int32)               # everyone -> expert 0
    w = jnp.ones((t, 1))
    cap = max(1, t // 4)
    y, m = dispatch_combine(x, slot, w, lambda b: b, e, cap)
    assert int(np.asarray(m["kept_counts"])[0]) == cap
    assert int(m["dropped"]) == t - cap


@seed(PYTEST_SEED)
@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 3))
def test_region_graph_partition_invariant(n_chain, n_blocking):
    """Regions always partition the op set; materializing every pipelined
    edge always yields a schedulable workflow."""
    from repro.core.regions import Op, Workflow, is_schedulable, regions
    wf = Workflow()
    names = [f"op{i}" for i in range(n_chain)]
    for i, n in enumerate(names):
        wf.add_op(Op(n, "op", 1.0, 1.0, 100 if i == 0 else 0))
    for i in range(n_chain - 1):
        wf.add_edge(names[i], names[i + 1],
                    blocking=(i < n_blocking))
    regs = regions(wf)
    all_ops = set()
    for r in regs:
        assert not (all_ops & r)
        all_ops |= r
    assert all_ops == set(names)
    full = wf.materialize(wf.pipelined_edges())
    assert is_schedulable(full)
