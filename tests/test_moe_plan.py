"""MoE + RoutingPlan semantics: plan-driven splits, state-migration +
plan-swap equivalence, reshaper convergence on skewed loads."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.reshape_moe import (Migration, MoEReshaper, SlotLayout,
                                    apply_migrations_np)
from repro.core.skew import SkewParams
from repro.models import lm
from repro.models import moe as moe_lib

CFG = get_arch("olmoe-1b-7b-smoke")     # 8 experts, top-2, 2 spare slots


def _params(key=0):
    return lm.init(CFG, jax.random.PRNGKey(key))


def _moe_block_params(params):
    # single moe layer slice
    return {k: v[0] for k, v in params["moe"].items()}


def test_identity_plan_routes_home():
    plan = moe_lib.identity_plan(CFG, 1)
    p = _moe_block_params(_params())
    x = jax.random.normal(jax.random.PRNGKey(1), (64, CFG.d_model))
    y, m = moe_lib.moe_ffn(p, x, plan.slots[0], plan.cum[0], CFG)
    # spare slots (8,9) receive nothing under the identity plan
    assert np.asarray(m["slot_counts"])[8:].sum() == 0
    assert np.isfinite(np.asarray(y)).all()


def test_sbr_split_fraction_obeyed():
    e, r = CFG.moe.num_experts, CFG.moe.max_replicas
    plan = moe_lib.identity_plan(CFG, 1)
    slots = np.asarray(plan.slots).copy()
    cum = np.asarray(plan.cum).copy()
    # split expert 0: 50% to spare slot 8, rest stays home
    slots[0, 0, 0] = 8
    slots[0, 0, 1:] = 0
    cum[0, 0, :] = 1.0
    cum[0, 0, 0] = 0.5
    p = _moe_block_params(_params())
    x = jax.random.normal(jax.random.PRNGKey(2), (512, CFG.d_model))
    y, m = moe_lib.moe_ffn(p, x, jnp.asarray(slots[0]), jnp.asarray(cum[0]),
                           CFG)
    counts = np.asarray(m["slot_counts"])
    routed_0 = counts[0] + counts[8]
    if routed_0 > 20:
        frac = counts[8] / routed_0
        assert 0.3 < frac < 0.7          # ~50% split via hashing


def test_migration_plus_split_preserves_function():
    """SBR correctness: copying expert-0 state into the spare slot and
    splitting its tokens gives the SAME outputs as no mitigation."""
    params = _params()
    p = _moe_block_params(params)
    # migrate expert 0 -> slot 8 (numpy reference migration)
    p2 = {k: (np.asarray(v).copy() if k != "router" else np.asarray(v))
          for k, v in p.items()}
    for k in ("w_gate", "w_up", "w_down"):
        p2[k][8] = p2[k][0]
    plan = moe_lib.identity_plan(CFG, 1)
    slots = np.asarray(plan.slots).copy()
    cum = np.asarray(plan.cum).copy()
    slots[0, 0, 0] = 8
    slots[0, 0, 1:] = 0
    cum[0, 0, 0] = 0.5
    x = jax.random.normal(jax.random.PRNGKey(3), (256, CFG.d_model))
    y_base, mb = moe_lib.moe_ffn(p, x, plan.slots[0], plan.cum[0], CFG)
    y_split, ms = moe_lib.moe_ffn(
        {k: jnp.asarray(v) for k, v in p2.items()}, x,
        jnp.asarray(slots[0]), jnp.asarray(cum[0]), CFG)
    if int(mb["dropped"]) == 0 and int(ms["dropped"]) == 0:
        np.testing.assert_allclose(np.asarray(y_base), np.asarray(y_split),
                                   atol=1e-4, rtol=1e-3)


def test_slot_layout_invariants():
    lay = SlotLayout(num_experts=64, ep_ranks=16)
    assert lay.slots_per_rank == 5 and lay.num_slots == 80
    for e in range(64):
        s = lay.home_slot(e)
        assert lay.rank_of_slot(s) == lay.rank_of_expert(e)
    spares = {lay.spare_slot(r) for r in range(16)}
    homes = {lay.home_slot(e) for e in range(64)}
    assert not (spares & homes)
    assert len(spares | homes) == 80


def test_reshaper_mitigates_synthetic_skew():
    cfg = get_arch("olmoe-1b-7b")
    rs = MoEReshaper(cfg, n_moe_layers=2, ep_ranks=16,
                     params=SkewParams(eta=0.0, tau=0.2), phase1_steps=1)
    rng = np.random.default_rng(0)
    e = cfg.moe.num_experts

    def skewed_counts():
        c = rng.integers(50, 100, (2, e)).astype(float)
        c[:, 0] = 4000.0                 # expert 0 (rank 0) red hot
        return c

    before = None
    for step in range(8):
        rs.observe(skewed_counts())
        slots, cum, migs = rs.step()
        if step == 0:
            before = rs.rank_loads(0).copy()
            # the hot expert must have been split or moved with migration
            assert migs, "expected a state migration for the hot expert"
    after = rs.rank_loads(0)
    assert after.max() < before.max()    # peak load reduced
    lb_before = before.min() / before.max()
    lb_after = after.min() / after.max()
    assert lb_after > lb_before


def test_apply_migrations_np():
    leaf = np.arange(2 * 4 * 3).reshape(2, 4, 3).astype(float)
    out = apply_migrations_np(leaf, [Migration(1, 0, 3)])
    np.testing.assert_array_equal(out[1, 3], leaf[1, 0])
    np.testing.assert_array_equal(out[0], leaf[0])


def test_runtime_migrate_matches_numpy():
    from repro.runtime.train import TrainHyper, build_grad_step, make_state
    state = make_state(CFG, jax.random.PRNGKey(0))
    # donate=False: this test reads the pre-migrate state afterwards, which
    # a donated (deleted) buffer would forbid on accelerator backends
    _, _, migrate = build_grad_step(CFG, TrainHyper(), donate=False)
    arr = jnp.asarray([[0, 1, 9], [1, 2, 8]], jnp.int32)
    new_state = migrate(state, arr)
    for k in ("w_gate", "w_up", "w_down"):
        ref = apply_migrations_np(np.asarray(state["params"]["moe"][k]),
                                  [Migration(0, 1, 9), Migration(1, 2, 8)])
        np.testing.assert_array_equal(
            np.asarray(new_state["params"]["moe"][k]), ref)
