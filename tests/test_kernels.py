"""Per-kernel validation: Pallas (interpret=True) + chunked-jnp vs the pure
sequential/naive oracle, swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

RNG = np.random.default_rng(0)


def randn(*s, dtype=np.float32, scale=1.0):
    return (RNG.standard_normal(s) * scale).astype(dtype)


# ------------------------------------------------------------ flash attention

ATTN_SHAPES = [(1, 2, 128, 64), (2, 3, 256, 64), (1, 1, 256, 128)]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(shape, causal, window, dtype):
    from repro.kernels.flash_attention.flash_attention import \
        flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_ref
    b, h, s, d = shape
    q, k, v = (randn(b, h, s, d).astype(dtype) for _ in range(3))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 bq=128, bk=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_chunked_jnp_matches_ref():
    from repro.models.attention import chunked_attention
    from repro.kernels.flash_attention.ref import attention_ref
    b, s, h, kh, d = 2, 192, 4, 2, 32
    q = randn(b, s, h, d)
    k = randn(b, s, kh, d)
    v = randn(b, s, kh, d)
    out = chunked_attention(q, k, v, causal=True, kv_chunk=64)
    from repro.models.attention import repeat_kv
    kr = repeat_kv(jnp.asarray(k), 2).transpose(0, 2, 1, 3)
    vr = repeat_kv(jnp.asarray(v), 2).transpose(0, 2, 1, 3)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kr, vr, causal=True)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(ref), atol=2e-5, rtol=1e-3)


# -------------------------------------------------------------------- gating

@pytest.mark.parametrize("t,e,k", [(256, 16, 4), (512, 64, 8), (128, 8, 2)])
def test_gating_kernel(t, e, k):
    from repro.kernels.moe_gating.moe_gating import gating_pallas
    from repro.kernels.moe_gating.ref import gating_ref
    logits = randn(t, e)
    w1, e1, c1 = gating_pallas(logits, k, bt=128)
    w2, e2, c2 = gating_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.sort(np.asarray(w1), -1),
                               np.sort(np.asarray(w2), -1), atol=1e-5,
                               rtol=1e-4)
    # same expert sets per row
    np.testing.assert_array_equal(np.sort(np.asarray(e1), -1),
                                  np.sort(np.asarray(e2), -1))


# ---------------------------------------------------------------- rwkv6 scan

@pytest.mark.parametrize("b,h,t,n,chunk", [(2, 2, 128, 32, 32),
                                           (1, 4, 64, 64, 16),
                                           (2, 1, 96, 16, 32)])
def test_rwkv6_chunked_and_pallas(b, h, t, n, chunk):
    from repro.kernels.rwkv6_scan.ref import rwkv6_ref
    from repro.kernels.rwkv6_scan.ops import rwkv6_chunked
    from repro.kernels.rwkv6_scan.rwkv6_scan import rwkv6_pallas
    r, k, v = (randn(b, h, t, n, scale=0.5) for _ in range(3))
    w = RNG.uniform(0.9, 0.999, (b, h, t, n)).astype(np.float32)
    u = randn(h, n, scale=0.1)
    s0 = randn(b, h, n, n, scale=0.1)
    y0, sT0 = rwkv6_ref(r, k, v, w, u, s0)
    y1, sT1 = rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=2e-3,
                               rtol=2e-2)
    np.testing.assert_allclose(np.asarray(sT1), np.asarray(sT0), atol=2e-3,
                               rtol=2e-2)
    y2, sT2 = rwkv6_pallas(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0), atol=2e-3,
                               rtol=2e-2)
    np.testing.assert_allclose(np.asarray(sT2), np.asarray(sT0), atol=2e-3,
                               rtol=2e-2)


def test_rwkv6_decode_step_matches_scan():
    from repro.kernels.rwkv6_scan.ref import rwkv6_ref
    from repro.kernels.rwkv6_scan.ops import rwkv6_decode_step
    b, h, t, n = 1, 2, 8, 16
    r, k, v = (randn(b, h, t, n, scale=0.5) for _ in range(3))
    w = RNG.uniform(0.9, 0.99, (b, h, t, n)).astype(np.float32)
    u = randn(h, n, scale=0.1)
    y_ref, _ = rwkv6_ref(r, k, v, w, u)
    s = jnp.zeros((b, h, n, n))
    ys = []
    for i in range(t):
        y, s = rwkv6_decode_step(r[:, :, i], k[:, :, i], v[:, :, i],
                                 w[:, :, i], jnp.asarray(u), s)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.stack(ys, 2), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-3)


# ---------------------------------------------------------------- mamba2 ssd

@pytest.mark.parametrize("b,h,t,p,n,chunk", [(2, 2, 128, 16, 8, 32),
                                             (1, 4, 64, 32, 16, 16)])
def test_mamba2_chunked_and_pallas(b, h, t, p, n, chunk):
    from repro.kernels.mamba2_ssd.ref import mamba2_ref
    from repro.kernels.mamba2_ssd.ops import mamba2_chunked
    from repro.kernels.mamba2_ssd.mamba2_ssd import mamba2_pallas
    x = randn(b, h, t, p)
    dt = RNG.uniform(0.01, 0.2, (b, h, t)).astype(np.float32)
    a = -RNG.uniform(0.5, 2.0, h).astype(np.float32)
    bm = randn(b, t, n)
    c = randn(b, t, n)
    d = randn(h, scale=0.1)
    h0 = randn(b, h, p, n, scale=0.1)
    y0, hT0 = mamba2_ref(x, dt, a, bm, c, d, h0)
    y1, hT1 = mamba2_chunked(x, dt, a, bm, c, d, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-3,
                               rtol=1e-2)
    y2, hT2 = mamba2_pallas(x, dt, a, bm, c, d, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0), atol=1e-3,
                               rtol=1e-2)
    np.testing.assert_allclose(np.asarray(hT2), np.asarray(hT0), atol=1e-3,
                               rtol=1e-2)


def test_mamba2_decode_matches_scan():
    from repro.kernels.mamba2_ssd.ref import mamba2_ref
    from repro.kernels.mamba2_ssd.ops import mamba2_decode_step
    b, h, t, p, n = 1, 2, 8, 8, 4
    x = randn(b, h, t, p)
    dt = RNG.uniform(0.01, 0.2, (b, h, t)).astype(np.float32)
    a = -RNG.uniform(0.5, 2.0, h).astype(np.float32)
    bm = randn(b, t, n)
    c = randn(b, t, n)
    d = randn(h, scale=0.1)
    y_ref, _ = mamba2_ref(x, dt, a, bm, c, d)
    hs = jnp.zeros((b, h, p, n))
    ys = []
    for i in range(t):
        y, hs = mamba2_decode_step(x[:, :, i], dt[:, :, i], jnp.asarray(a),
                                   bm[:, i], c[:, i], jnp.asarray(d), hs)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.stack(ys, 2), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-3)
