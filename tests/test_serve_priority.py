"""Priority-aware multi-pool serving: weighted-FRT arbitration, per-class
aging bounds (the starvation regression), class->pool routing, and output
bit-identicality of the scheduled paths against the static oracle.

The scheduling layer may only ever REORDER work: whatever the weights,
pools, and aging bounds do to the tick order, every request's greedy output
must match ``BatchedServer.generate_static`` token for token (the same
invariant the differential harness sweeps; here it is pinned on the
priority-specific paths)."""
import dataclasses

import numpy as np
import pytest
import jax

from repro.configs.base import PriorityClass
from repro.core.regions import Op, Workflow
from repro.core.scheduler import CostModel, score_choices
from repro.engine import Engine, ServeEngine, TickCandidate

from test_serve_differential import CFG, MAX_LEN, _fixture, oracle

# hi outweighs lo 8:1; lo tolerates sitting out at most 3 scheduled ticks
CLASSES = (PriorityClass("hi", 8.0, 6), PriorityClass("lo", 1.0, 3))
CFG_PRIO = dataclasses.replace(
    CFG, serve=dataclasses.replace(CFG.serve, classes=CLASSES))


def _prio_engine(**kw):
    params, _ = _fixture()
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("decode_chunk", 2)
    return ServeEngine(CFG_PRIO, params, **kw)


# ------------------------------------------------------- engine unit tests

def test_weighted_frt_flips_pool_choice_when_weights_flip():
    """Two identical decode candidates on two pools: the class weight is
    the only difference, so the heavier class must win — and flipping the
    weights must flip the pool the engine picks."""
    eng = Engine()

    def cands(w0, w1):
        return [TickCandidate(0, "decode", n_dec=2, chunk=4, weight=w0),
                TickCandidate(1, "decode", n_dec=2, chunk=4, weight=w1)]

    assert eng.choose_serve_job(cands(8.0, 1.0)) == (0, "decode")
    assert eng.choose_serve_job(cands(1.0, 8.0)) == (1, "decode")


def test_weighted_frt_flips_composition_when_weights_flip():
    """Same pool, decode vs prefill: unweighted min-FRT always prefers the
    short decode tick, but enough class weight behind the waiting prefill
    flips the composition — the result-aware arbitration at work."""
    eng = Engine()

    def cands(w_dec, w_pre):
        return [TickCandidate(0, "decode", n_dec=1, chunk=4, weight=w_dec),
                TickCandidate(0, "prefill", n_dec=1, n_pre=1, pre_toks=4,
                              chunk=4, weight=w_pre)]

    assert eng.choose_serve_job(cands(1.0, 50.0)) == (0, "prefill")
    assert eng.choose_serve_job(cands(50.0, 1.0)) == (0, "decode")


def test_aged_candidate_overrides_any_weight():
    """A candidate past its aging bound evicts every non-aged candidate
    from the round, whatever the weighted scores say."""
    eng = Engine()
    got = eng.choose_serve_job([
        TickCandidate(0, "decode", n_dec=4, chunk=2, weight=1e6),
        TickCandidate(1, "prefill", n_pre=1, pre_toks=8, chunk=4,
                      weight=1e-3, aged=True)])
    assert got == (1, "prefill")
    assert eng.decisions[-1]["aged"] is True


def test_most_overdue_aged_candidate_wins():
    eng = Engine()
    got = eng.choose_serve_job([
        TickCandidate(0, "prefill", n_pre=1, pre_toks=4, chunk=4,
                      weight=9.0, aged=True, overdue=0),
        TickCandidate(1, "prefill", n_pre=1, pre_toks=4, chunk=4,
                      weight=1.0, aged=True, overdue=3)])
    assert got == (1, "prefill")


def test_pool_cost_emas_steer_the_arbitration():
    """The per-pool parallelism term: identical candidates, but pool 1's
    measured per-token EMA is 10x cheaper, so pool 1 wins the round."""
    from repro.engine.jobs import pool_kind
    eng = Engine()
    for _ in range(2):                      # first observation is warm-up
        eng.costs.observe(pool_kind("serve_decode", 0) + "_per_tok", 1e-2)
        eng.costs.observe(pool_kind("serve_decode", 1) + "_per_tok", 1e-3)
    got = eng.choose_serve_job(
        [TickCandidate(0, "decode", n_dec=2, chunk=4, weight=1.0),
         TickCandidate(1, "decode", n_dec=2, chunk=4, weight=1.0)])
    assert got == (1, "decode")


def test_score_choices_weight_divides_scores():
    wf = Workflow()
    wf.add_op(Op("src", "scan", cost_per_tuple=0.0, source_cardinality=4.0))
    wf.add_op(Op("work", "ml", cost_per_tuple=0.5))
    wf.add_op(Op("out", "sink", cost_per_tuple=0.0))
    wf.add_edge("src", "work")
    wf.add_edge("work", "out")
    cm = CostModel()
    base = score_choices(wf, cm, "frt")
    heavy = score_choices(wf, cm, "frt", weight=4.0)
    assert heavy[0][0] == pytest.approx(base[0][0] / 4.0)


# -------------------------------------------------- serve-engine behaviour

def test_starvation_regression_low_priority_prefill_bounded():
    """THE aging regression: a saturating high-priority decode stream must
    not defer an admitted low-priority prefill past its class's max_defer
    — and must defer it at least once (otherwise priorities did nothing)."""
    eng = _prio_engine(slots=3, pools=1)
    rng = np.random.default_rng(11)
    hi = [eng.submit(rng.integers(1, CFG.vocab, (3,)).astype(np.int32),
                     max_new=40, priority="hi") for _ in range(2)]
    # drain the hi prefills so the stream is pure decode pressure
    while any(r.prefilling for r in hi):
        assert eng.tick()
    lo_prompt = rng.integers(1, CFG.vocab, (8,)).astype(np.int32)
    lo = eng.submit(lo_prompt, max_new=2, priority="lo")
    while not lo.done.is_set():
        assert eng.tick()
    bound = dict((c.name, c.max_defer) for c in CLASSES)["lo"]
    assert 1 <= lo.max_deferred <= bound, \
        f"lo deferred {lo.max_deferred}, bound {bound}"
    # the forced prefill must show up as an aged decision
    assert any(d.get("aged") for d in eng.engine.decisions
               if d["decision"] == "serve_job")
    eng.run_until_done()
    np.testing.assert_array_equal(lo.output(), oracle(lo_prompt, 2))
    for r in hi:
        assert len(r.output()) == 40


def test_class_pool_routing_pins_admission():
    eng = _prio_engine(slots=2, pools=2,
                       class_pools={"hi": (0,), "lo": (1,)})
    rng = np.random.default_rng(5)
    reqs = [eng.submit(rng.integers(1, CFG.vocab, (4,)).astype(np.int32),
                       max_new=2, priority=p)
            for p in ("hi", "lo", "hi", "lo")]
    eng._admit()
    assert [r.pool for r in reqs] == [0, 1, 0, 1]
    eng.run_until_done()
    assert all(r.done.is_set() for r in reqs)


def test_full_class_pools_do_not_block_other_traffic():
    """Head-of-line: when a class's pools are all full, later requests
    bound for a free pool must still be admitted."""
    eng = _prio_engine(slots=1, pools=2,
                       class_pools={"hi": (0,), "lo": (1,)})
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, CFG.vocab, (3,)).astype(np.int32)
               for _ in range(3)]
    r_hi0 = eng.submit(prompts[0], max_new=2, priority="hi")
    r_hi1 = eng.submit(prompts[1], max_new=2, priority="hi")  # pool 0 full
    r_lo = eng.submit(prompts[2], max_new=2, priority="lo")
    eng._admit()
    assert r_hi0.pool == 0 and r_hi1.pool == -1 and r_lo.pool == 1
    eng.run_until_done()
    assert all(r.done.is_set() for r in (r_hi0, r_hi1, r_lo))


def test_priority_outputs_bit_identical_across_pools():
    """Scheduling reorders work, never changes results: mixed classes over
    one and two pools all reproduce the static-oracle outputs exactly."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, CFG.vocab, (l,)).astype(np.int32)
               for l in (2, 7, 11, 4, 9)]
    news = [int(rng.integers(1, 8)) for _ in prompts]
    prios = ["hi", "lo", "hi", "lo", "hi"]
    for pools in (1, 2):
        eng = _prio_engine(slots=2, pools=pools)
        reqs = [eng.submit(p, max_new=n, priority=pr)
                for p, n, pr in zip(prompts, news, prios)]
        eng.run_until_done()
        for p, n, r in zip(prompts, news, reqs):
            np.testing.assert_array_equal(
                r.output(), oracle(p, n),
                err_msg=f"pools={pools} plen={len(p)} max_new={n}")


def test_single_pool_single_class_keeps_legacy_decision_path():
    """The default table must take the ORIGINAL choose_serve_tick path
    (decision-identical, not just output-identical, to the pre-priority
    engine) — pinned so a refactor cannot silently reroute it."""
    params, _ = _fixture()
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2)
    assert eng.single_pool
    eng.submit(np.arange(1, 7, dtype=np.int32), max_new=4)
    eng.run_until_done()
    kinds = {d["decision"] for d in eng.engine.decisions}
    assert "serve_tick" in kinds and "serve_job" not in kinds
    prio = _prio_engine(slots=2, pools=1)
    assert not prio.single_pool
