"""Prefill/decode consistency: feeding tokens one-by-one through the decode
path must reproduce the full-sequence forward logits — the strongest cache
correctness check, run per architecture family."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import lm

FAMS = ["yi-34b", "gemma3-1b", "olmoe-1b-7b", "rwkv6-1.6b", "zamba2-7b",
        "whisper-base"]

# numeric tolerance per family: bf16 residual accumulation differs between
# the chunked full-sequence path and the step-by-step decode path; deeper
# mixed stacks (zamba2) accumulate more.
ATOL = {"zamba2-7b": 0.25, "whisper-base": 0.15}


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = get_arch(arch + "-smoke")
    if cfg.moe is not None:
        # capacity drops differ between a 24-token forward and 1-token
        # decode; raise capacity so the consistency check sees no drops
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens}
    enc_out = None
    if cfg.enc_layers:
        frames = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)) * 0.1,
            jnp.float32)
        batch["frames"] = frames
    ref_logits, _ = lm.forward(params, batch, cfg)

    state = lm.init_cache(cfg, b, s + 4)
    if cfg.enc_layers:
        # precompute cross K/V like a real prefill would
        enc = lm.encode(params, frames.astype(jnp.bfloat16), cfg)
        p = params["dec"]
        kh, hd = cfg.n_kv_heads, cfg.hd
        ck = jnp.einsum("lbsd,ldq->lbsq", jnp.broadcast_to(
            enc[None], (cfg.num_layers,) + enc.shape), p["cwk"]).reshape(
            cfg.num_layers, b, cfg.enc_seq, kh, hd)
        cv = jnp.einsum("lbsd,ldq->lbsq", jnp.broadcast_to(
            enc[None], (cfg.num_layers,) + enc.shape), p["cwv"]).reshape(
            cfg.num_layers, b, cfg.enc_seq, kh, hd)
        state["caches"]["dec"]["ck"] = ck.astype(jnp.bfloat16)
        state["caches"]["dec"]["cv"] = cv.astype(jnp.bfloat16)

    outs = []
    for i in range(s):
        lg, state = lm.decode_step(params, state, tokens[:, i:i + 1], cfg)
        outs.append(np.asarray(lg))
    got = np.stack(outs, axis=1)
    ref = np.asarray(ref_logits)
    atol = ATOL.get(arch, 0.08)
    np.testing.assert_allclose(got, ref, atol=atol, rtol=0.1)
