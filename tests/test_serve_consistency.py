"""Prefill/decode consistency: feeding tokens one-by-one through the decode
path must reproduce the full-sequence forward logits — the strongest cache
correctness check, run per architecture family.  Plus serve-under-control:
control messages delivered between ServeEngine decode ticks must leave the
generated tokens untouched."""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core import messages as M
from repro.models import lm

FAMS = ["yi-34b", "gemma3-1b", "olmoe-1b-7b", "rwkv6-1.6b", "zamba2-7b",
        "whisper-base"]

# numeric tolerance per family: bf16 residual accumulation differs between
# the chunked full-sequence path and the step-by-step decode path; deeper
# mixed stacks (zamba2) accumulate more.
ATOL = {"zamba2-7b": 0.25, "whisper-base": 0.15}


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = get_arch(arch + "-smoke")
    if cfg.moe is not None:
        # capacity drops differ between a 24-token forward and 1-token
        # decode; raise capacity so the consistency check sees no drops
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens}
    enc_out = None
    if cfg.enc_layers:
        frames = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)) * 0.1,
            jnp.float32)
        batch["frames"] = frames
    ref_logits, _ = lm.forward(params, batch, cfg)

    state = lm.init_cache(cfg, b, s + 4)
    if cfg.enc_layers:
        # precompute cross K/V like a real prefill would
        enc = lm.encode(params, frames.astype(jnp.bfloat16), cfg)
        p = params["dec"]
        kh, hd = cfg.n_kv_heads, cfg.hd
        ck = jnp.einsum("lbsd,ldq->lbsq", jnp.broadcast_to(
            enc[None], (cfg.num_layers,) + enc.shape), p["cwk"]).reshape(
            cfg.num_layers, b, cfg.enc_seq, kh, hd)
        cv = jnp.einsum("lbsd,ldq->lbsq", jnp.broadcast_to(
            enc[None], (cfg.num_layers,) + enc.shape), p["cwv"]).reshape(
            cfg.num_layers, b, cfg.enc_seq, kh, hd)
        state["caches"]["dec"]["ck"] = ck.astype(jnp.bfloat16)
        state["caches"]["dec"]["cv"] = cv.astype(jnp.bfloat16)

    outs = []
    for i in range(s):
        lg, state = lm.decode_step(params, state, tokens[:, i:i + 1], cfg)
        outs.append(np.asarray(lg))
    got = np.stack(outs, axis=1)
    ref = np.asarray(ref_logits)
    atol = ATOL.get(arch, 0.08)
    np.testing.assert_allclose(got, ref, atol=atol, rtol=0.1)


# ------------------------------------------------------- serve under control

def _mk_engine(cfg, params, **kw):
    from repro.engine import ServeEngine
    kw.setdefault("max_len", 64)
    kw.setdefault("slots", 2)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_chunk", 4)
    return ServeEngine(cfg, params, **kw)


def test_serve_pause_inspect_resume_between_ticks_keeps_tokens():
    """Pause/Inspect/Update/Resume delivered mid-generation must not change
    a single generated token vs an uninterrupted run — the control plane is
    on the tick boundary, outside the data plane."""
    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompts = rng.integers(1, cfg.vocab, (3, 9)).astype(np.int32)

    ref = _mk_engine(cfg, params).generate(prompts, max_new=12)

    eng = _mk_engine(cfg, params)
    ctl = eng.engine.controller
    reqs = [eng.submit(p, max_new=12) for p in prompts]
    # deterministic delivery: run a few ticks, then park control messages in
    # the mailbox; the next tick's poll applies them all (pause is answered,
    # inspect is served WHILE paused, resume releases the loop)
    for _ in range(2):
        eng.tick()
    ctl.send(M.pause())
    insp = ctl.send(M.inspect())
    ctl.send(M.update(max_prefill_defer=7))
    ctl.send(M.resume())
    eng.run_until_done()
    info = insp.wait(30)
    assert info["paused"] is True            # answered from inside the pause
    assert info["tick"] >= 2 and "slots" in info
    assert eng.engine.max_prefill_defer == 7
    got = np.stack([r.output() for r in reqs])
    np.testing.assert_array_equal(got, ref)
    kinds = [r.kind for r in ctl.log]
    assert kinds.count("pause") == 1 and kinds.count("resume") == 1


def test_serve_durable_log_replay_of_control_messages(tmp_path):
    """Serve-side pause/update/breakpoint/resume delivered mid-generation
    are durably logged at their tick position; after a 'crash', a
    ReplayingController re-applies the state-effecting records at their
    recorded ticks on a fresh ServeEngine and the regenerated outputs are
    bit-identical — §2.6.2 recovery, which PR 2 gave training
    (test_controller_ft), now exercised on the serving control plane."""
    from repro.core.breakpoints import GlobalCountBreakpoint
    from repro.core.controller import Controller, ReplayingController
    from repro.engine import Engine

    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(17)
    prompts = rng.integers(1, cfg.vocab, (3, 7)).astype(np.int32)
    path = str(tmp_path / "serve_control.log")

    eng = _mk_engine(cfg, params, engine=Engine(durable_log=path))
    ctl = eng.engine.controller
    reqs = [eng.submit(p, max_new=10) for p in prompts]
    for _ in range(2):
        eng.tick()
    ctl.send(M.pause())
    ctl.send(M.update(max_prefill_defer=6, decode_chunk=2))
    ctl.send(M.set_breakpoint(
        GlobalCountBreakpoint("budget", "emitted", target=10**9)))
    ctl.send(M.resume())
    eng.run_until_done()
    ref = np.stack([r.output() for r in reqs])
    del eng                                       # "crash"

    records = Controller.read_durable_log(path)
    assert [r.kind for r in records] == ["pause", "update", "breakpoint",
                                         "resume"]
    assert all(r.step == 2 for r in records)      # tick 2's poll point
    bp = records[2].payload
    assert isinstance(bp, GlobalCountBreakpoint)  # restored as the class,
    assert bp.target == 10**9                     # not a field dict

    rc = ReplayingController(records)
    eng2 = _mk_engine(cfg, params, engine=Engine(controller=rc))
    reqs2 = [eng2.submit(p, max_new=10) for p in prompts]
    eng2.run_until_done()
    np.testing.assert_array_equal(np.stack([r.output() for r in reqs2]), ref)
    # the replayed state effects landed at their recorded tick
    assert eng2.engine.max_prefill_defer == 6
    assert eng2.decode_chunk == 2
    assert any(getattr(b, "name", "") == "budget"
               for b in eng2.engine.global_bps)


def test_serve_durable_log_replay_with_firing_breakpoint(tmp_path):
    """A global token-budget breakpoint that FIRES mid-generation (pausing
    the stream) must replay cleanly: the recovered engine re-registers it
    from the log, it fires again at the same budget, and the regenerated
    tokens are bit-identical."""
    from repro.core.breakpoints import GlobalCountBreakpoint
    from repro.core.controller import Controller, ReplayingController
    from repro.engine import Engine

    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(1, 7, dtype=np.int32)

    def run(eng):
        req = eng.submit(prompt, max_new=12)
        resumer = threading.Thread(target=lambda: (
            _wait_paused(eng), eng.engine.controller.send(M.resume())))
        resumer.start()
        eng.run_until_done()
        resumer.join()
        return req.output()

    def _wait_paused(eng):
        while not eng.engine.controller.paused:
            time.sleep(0.01)

    path = str(tmp_path / "bp.log")
    eng = _mk_engine(cfg, params, engine=Engine(durable_log=path),
                     decode_chunk=2)
    eng.engine.controller.send(M.set_breakpoint(
        GlobalCountBreakpoint("tok-budget", "emitted", target=4)))
    ref = run(eng)
    assert "tok-budget" in eng.hit_breakpoints
    del eng

    records = Controller.read_durable_log(path)
    kinds = [r.kind for r in records]
    assert "breakpoint" in kinds and "resume" in kinds
    # replay: _total must restore to its logged (pre-fire) value so the
    # budget fires at the same point in the regenerated stream
    eng2 = _mk_engine(cfg, params,
                      engine=Engine(controller=ReplayingController(records)),
                      decode_chunk=2)
    got = run(eng2)
    assert "tok-budget" in eng2.hit_breakpoints
    np.testing.assert_array_equal(got, ref)


def test_serve_pause_latency_is_tick_bounded():
    """An async pause lands at the next tick boundary, and the engine keeps
    answering inspect while paused (the §2.4.4 capability, now on serving)."""
    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    eng = _mk_engine(cfg, params, decode_chunk=2)
    ctl = eng.engine.controller
    eng.submit(np.arange(1, 8, dtype=np.int32), max_new=20)
    state = {}

    def driver():
        r = ctl.send(M.pause()).wait(60)
        state["paused_at"] = r["paused_at"]
        state["inspect"] = ctl.send(M.inspect()).wait(60)
        ctl.send(M.resume()).wait(60)

    th = threading.Thread(target=driver)
    th.start()
    time.sleep(0.05)
    eng.run_until_done()
    th.join()
    assert "paused_at" in state
    assert state["inspect"]["paused"] is True
    assert not eng.queue and all(r is None for r in eng.active)
