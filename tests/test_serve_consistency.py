"""Prefill/decode consistency: feeding tokens one-by-one through the decode
path must reproduce the full-sequence forward logits — the strongest cache
correctness check, run per architecture family.  Plus serve-under-control:
control messages delivered between ServeEngine decode ticks must leave the
generated tokens untouched."""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core import messages as M
from repro.models import lm

FAMS = ["yi-34b", "gemma3-1b", "olmoe-1b-7b", "rwkv6-1.6b", "zamba2-7b",
        "whisper-base"]

# numeric tolerance per family: bf16 residual accumulation differs between
# the chunked full-sequence path and the step-by-step decode path; deeper
# mixed stacks (zamba2) accumulate more.
ATOL = {"zamba2-7b": 0.25, "whisper-base": 0.15}


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = get_arch(arch + "-smoke")
    if cfg.moe is not None:
        # capacity drops differ between a 24-token forward and 1-token
        # decode; raise capacity so the consistency check sees no drops
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens}
    enc_out = None
    if cfg.enc_layers:
        frames = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)) * 0.1,
            jnp.float32)
        batch["frames"] = frames
    ref_logits, _ = lm.forward(params, batch, cfg)

    state = lm.init_cache(cfg, b, s + 4)
    if cfg.enc_layers:
        # precompute cross K/V like a real prefill would
        enc = lm.encode(params, frames.astype(jnp.bfloat16), cfg)
        p = params["dec"]
        kh, hd = cfg.n_kv_heads, cfg.hd
        ck = jnp.einsum("lbsd,ldq->lbsq", jnp.broadcast_to(
            enc[None], (cfg.num_layers,) + enc.shape), p["cwk"]).reshape(
            cfg.num_layers, b, cfg.enc_seq, kh, hd)
        cv = jnp.einsum("lbsd,ldq->lbsq", jnp.broadcast_to(
            enc[None], (cfg.num_layers,) + enc.shape), p["cwv"]).reshape(
            cfg.num_layers, b, cfg.enc_seq, kh, hd)
        state["caches"]["dec"]["ck"] = ck.astype(jnp.bfloat16)
        state["caches"]["dec"]["cv"] = cv.astype(jnp.bfloat16)

    outs = []
    for i in range(s):
        lg, state = lm.decode_step(params, state, tokens[:, i:i + 1], cfg)
        outs.append(np.asarray(lg))
    got = np.stack(outs, axis=1)
    ref = np.asarray(ref_logits)
    atol = ATOL.get(arch, 0.08)
    np.testing.assert_allclose(got, ref, atol=atol, rtol=0.1)


# ------------------------------------------------------- serve under control

def _mk_engine(cfg, params, **kw):
    from repro.engine import ServeEngine
    kw.setdefault("max_len", 64)
    kw.setdefault("slots", 2)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_chunk", 4)
    return ServeEngine(cfg, params, **kw)


def test_serve_pause_inspect_resume_between_ticks_keeps_tokens():
    """Pause/Inspect/Update/Resume delivered mid-generation must not change
    a single generated token vs an uninterrupted run — the control plane is
    on the tick boundary, outside the data plane."""
    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompts = rng.integers(1, cfg.vocab, (3, 9)).astype(np.int32)

    ref = _mk_engine(cfg, params).generate(prompts, max_new=12)

    eng = _mk_engine(cfg, params)
    ctl = eng.engine.controller
    reqs = [eng.submit(p, max_new=12) for p in prompts]
    # deterministic delivery: run a few ticks, then park control messages in
    # the mailbox; the next tick's poll applies them all (pause is answered,
    # inspect is served WHILE paused, resume releases the loop)
    for _ in range(2):
        eng.tick()
    ctl.send(M.pause())
    insp = ctl.send(M.inspect())
    ctl.send(M.update(max_prefill_defer=7))
    ctl.send(M.resume())
    eng.run_until_done()
    info = insp.wait(30)
    assert info["paused"] is True            # answered from inside the pause
    assert info["tick"] >= 2 and "slots" in info
    assert eng.engine.max_prefill_defer == 7
    got = np.stack([r.output() for r in reqs])
    np.testing.assert_array_equal(got, ref)
    kinds = [r.kind for r in ctl.log]
    assert kinds.count("pause") == 1 and kinds.count("resume") == 1


def test_serve_pause_latency_is_tick_bounded():
    """An async pause lands at the next tick boundary, and the engine keeps
    answering inspect while paused (the §2.4.4 capability, now on serving)."""
    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    eng = _mk_engine(cfg, params, decode_chunk=2)
    ctl = eng.engine.controller
    eng.submit(np.arange(1, 8, dtype=np.int32), max_new=20)
    state = {}

    def driver():
        r = ctl.send(M.pause()).wait(60)
        state["paused_at"] = r["paused_at"]
        state["inspect"] = ctl.send(M.inspect()).wait(60)
        ctl.send(M.resume()).wait(60)

    th = threading.Thread(target=driver)
    th.start()
    time.sleep(0.05)
    eng.run_until_done()
    th.join()
    assert "paused_at" in state
    assert state["inspect"]["paused"] is True
    assert not eng.queue and all(r is None for r in eng.active)
