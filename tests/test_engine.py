"""The unified engine layer: Maestro decisions over measured job costs,
continuous-batching ServeEngine (join/evict, chunked prefill, min-FRT tick
composition), the TrainLoop-as-engine-client refactor, and the granulated
apply/migrate donation audit."""
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import messages as M
from repro.core.breakpoints import GlobalCountBreakpoint
from repro.core.estimator import CostBook
from repro.core.scheduler import CostModel, completion_time, score_choices
from repro.data.synthetic import TokenStream
from repro.engine import (Engine, Job, ServeEngine, serve_tick_workflow,
                          train_step_workflow)
from repro.models import lm
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.runtime.train import TrainHyper, build_grad_step, make_state


def _params(arch="gemma3-1b-smoke"):
    cfg = get_arch(arch)
    return cfg, lm.init(cfg, jax.random.PRNGKey(0))


# ------------------------------------------------------------ cost book

def test_costbook_warmup_skip_and_ema():
    eng = Engine()
    eng.observe(Job("k", tokens=10), 99.0)      # warm-up (compile) discarded
    assert eng.costs.estimate("k") is None
    eng.observe(Job("k", tokens=10), 1.0)
    assert abs(eng.costs.estimate("k") - 1.0) < 1e-9
    assert abs(eng.costs.estimate("k_per_tok") - 0.1) < 1e-9
    assert eng.jobs_run["k"] == 2
    assert "k" in eng.costs.snapshot()


def test_costbook_default_until_measured():
    cb = CostBook()
    assert cb.estimate("missing") is None
    assert cb.estimate("missing", 0.5) == 0.5
    cb.observe("missing", 2.0)
    assert cb.estimate("missing", 0.5) == 2.0


# ----------------------------------------------------- job/region workflows

def test_train_step_workflow_frt_vs_completion():
    """Granulated: first response after ONE microbatch but a longer drain;
    fused: one region — FRT equals completion.  This asymmetry IS the
    step-path decision."""
    from repro.core.scheduler import first_response_time
    cm = CostModel()
    t_mb, n_mb = 0.1, 4
    wf_g = train_step_workflow("granulated", n_mb, t_mb, t_apply=0.02)
    wf_f = train_step_workflow("fused", n_mb, 0.08, t_apply=0.02)
    frt_g = first_response_time(wf_g, frozenset(), cm)
    frt_f = first_response_time(wf_f, frozenset(), cm)
    assert abs(frt_g - t_mb) < 1e-9              # one microbatch
    assert abs(frt_f - (4 * 0.08 + 0.02)) < 1e-9  # the whole fused step
    assert frt_g < frt_f
    assert completion_time(wf_f, cm) < completion_time(wf_g, cm)


def test_serve_tick_workflow_decode_preempts_prefill():
    cm = CostModel()
    from repro.core.scheduler import first_response_time
    wf_d = serve_tick_workflow(2, 4, 0, t_token=0.01)
    wf_p = serve_tick_workflow(2, 16, 64, t_token=0.01)
    frt_d = first_response_time(wf_d, frozenset(), cm)
    frt_p = first_response_time(wf_p, frozenset(), cm)
    assert frt_d < frt_p                         # short decode wins on FRT
    sc = score_choices(wf_p, cm, objective="frt")
    assert sc[0][0] == pytest.approx(frt_p)


# ------------------------------------------------------------ engine choices

def test_choose_step_path_interactive_forces_granulated():
    eng = Engine()
    assert eng.choose_step_path("auto", 2) == "fused"     # idle + priors
    eng.controller.mailbox.put(M.inspect())
    assert eng.choose_step_path("auto", 2) == "granulated"
    eng.controller.mailbox.get_nowait()
    eng.controller.paused = True
    assert eng.choose_step_path("auto", 2) == "granulated"
    eng.controller.paused = False
    assert eng.choose_step_path("fused", 2) == "fused"    # forced wins
    assert eng.choose_step_path("granulated", 2) == "granulated"


def test_choose_step_path_follows_measured_costs():
    eng = Engine()
    for t in (0.2, 0.2):                  # first observation is warm-up
        eng.observe(Job("train_step_fused"), t)
        eng.observe(Job("train_step_fused"), t)
    for t in (0.05, 0.05):
        eng.observe(Job("train_step_granulated"), t)
        eng.observe(Job("train_step_granulated"), t)
    # measured costs say granulated is cheaper -> the cost model, not the
    # old hard-coded heuristic, decides
    assert eng.choose_step_path("auto", 2) == "granulated"
    assert eng.decisions[-1]["scores"]["granulated"] < \
        eng.decisions[-1]["scores"]["fused"]


def test_costbook_observe_rate_clamps_to_unit_interval():
    cb = CostBook()
    cb.observe_rate("acc", 1.7)
    assert cb.estimate("acc") == 1.0
    for _ in range(30):
        cb.observe_rate("acc", -3.0)
    assert cb.estimate("acc") >= 0.0


def test_serve_decode_workflow_commit_cardinality_tracks_acceptance():
    """The spec arm's sink cardinality is the expected committed-token
    count; the verify region's time is paid regardless — the speculative
    gamble the arm decision prices."""
    from repro.engine import serve_decode_workflow
    from repro.core.scheduler import cardinalities
    cm = CostModel()
    wf_hi = serve_decode_workflow("spec", 2, 4, 1e-4, accept=1.0)
    wf_lo = serve_decode_workflow("spec", 2, 4, 1e-4, accept=0.0)
    assert cardinalities(wf_hi)["stream_out"] == pytest.approx(2 * 4)
    assert cardinalities(wf_lo)["stream_out"] == pytest.approx(2 * 1)
    # same verify work either way
    assert completion_time(wf_hi, cm) == pytest.approx(
        completion_time(wf_lo, cm))


def test_choose_serve_tick_spec_arm_switches_on_measured_acceptance():
    """The acceptance-criteria test: with measured runtimes fixed, driving
    the pool's acceptance-rate EMA high vs low flips the decode arm."""
    from repro.engine import spec_kind
    eng = Engine()
    # fresh engine explores the speculative arm first: acceptance can only
    # be measured by running it
    assert eng.choose_serve_tick(2, 0, 0, 4, 16, spec_len=4) == "spec:ngram"
    # measured: the verify step is a bit cheaper per scan step than the
    # sampling decode step (first observation per kind is warm-up-skipped)
    for _ in range(3):
        eng.observe(Job("serve_decode", tokens=100), 1.0e-2)
        eng.observe(Job(spec_kind("ngram"), tokens=100), 0.8e-2)
    for _ in range(4):
        eng.observe_accept(0, 0.9)
    assert eng.choose_serve_tick(2, 0, 0, 4, 16, spec_len=4) == "spec:ngram"
    assert eng.decisions[-1]["scores"]["spec:ngram"] < \
        eng.decisions[-1]["scores"]["decode"]
    # an incompressible workload drives acceptance to ~0: the expected
    # commits collapse to 1 per tick and the plain arm wins back
    for _ in range(12):
        eng.observe_accept(0, 0.0)
    assert eng.choose_serve_tick(2, 0, 0, 4, 16, spec_len=4) == "decode"
    assert eng.decisions[-1]["scores"]["decode"] < \
        eng.decisions[-1]["scores"]["spec:ngram"]
    # no speculative offer -> plain decode, regardless of EMAs
    assert eng.choose_serve_tick(2, 0, 0, 4, 16, spec_len=0) == "decode"


def test_choose_serve_tick_spec_arm_reexplores_loser():
    from repro.engine import spec_kind
    eng = Engine()
    for _ in range(3):
        eng.observe(Job("serve_decode", tokens=100), 1.0e-2)
        eng.observe(Job(spec_kind("ngram"), tokens=100), 1.0e-2)
    for _ in range(8):
        eng.observe_accept(0, 0.0)        # spec is the losing arm
    picks = [eng.choose_serve_tick(2, 0, 0, 4, 16, spec_len=4)
             for _ in range(16)]
    assert picks[:15] == ["decode"] * 15
    assert picks[15] == "spec:ngram"      # every 16th round re-explores
    assert eng.decisions[-1]["why"] == "re-explore"


def test_choose_decode_arm_family_prices_each_proposer():
    """Three-arm family {plain, spec:ngram, spec:draft}: each spec arm is
    bootstrapped independently, then priced from its OWN acceptance and
    runtime EMAs — a strong draft beats both the plain arm and a collapsed
    ngram arm, and per-arm acceptance keeps them distinguishable."""
    from repro.engine import spec_kind
    eng = Engine()
    arms = ("ngram", "draft")
    # both spec arms bootstrap first (each needs its own EMAs)
    first = eng.choose_serve_tick(2, 0, 0, 4, 16, spec_len=4, arms=arms)
    assert first.startswith("spec:")
    assert eng.decisions[-1]["why"] == "bootstrap"
    for _ in range(3):
        eng.observe(Job("serve_decode", tokens=100), 1.0e-2)
        eng.observe(Job(spec_kind("ngram"), tokens=100), 0.8e-2)
        eng.observe(Job(spec_kind("draft"), tokens=100), 0.9e-2)
    # ngram collapsed on this workload, the draft keeps proposing well
    for _ in range(8):
        eng.observe_accept(0, 0.05, arm="ngram")
        eng.observe_accept(0, 0.9, arm="draft")
    pick = eng.choose_serve_tick(2, 0, 0, 4, 16, spec_len=4, arms=arms)
    assert pick == "spec:draft"
    scores = eng.decisions[-1]["scores"]
    assert set(scores) == {"decode", "spec:ngram", "spec:draft"}
    assert scores["spec:draft"] < scores["decode"] < scores["spec:ngram"]
    # telemetry carries the CostBook inputs the decision saw
    inputs = eng.decisions[-1]["inputs"]
    assert inputs["accept:draft"] > inputs["accept:ngram"]
    # a measured ngram tick must NOT suppress the draft arm's bootstrap:
    # per-arm runtimes have no aggregate fallback
    eng2 = Engine()
    for _ in range(3):
        eng2.observe(Job("serve_decode", tokens=100), 1.0e-2)
        eng2.observe(Job(spec_kind("ngram"), tokens=100), 0.8e-2)
    for _ in range(4):
        eng2.observe_accept(0, 0.5, arm="ngram")
    assert eng2.choose_serve_tick(2, 0, 0, 4, 16, spec_len=4,
                                  arms=arms) == "spec:draft"
    assert eng2.decisions[-1]["why"] == "bootstrap"


def test_choose_compact_is_a_measured_layout_arm():
    """Tick layout (compact gather vs full-pool vmap) is decided from
    per-pool per-token EMAs recorded on layout-eligible ticks."""
    from repro.engine import layout_kind
    eng = Engine()
    # bootstrap: try compact first (its EMA can only come from running it)
    assert eng.choose_compact(0) is True
    assert eng.decisions[-1]["why"] == "bootstrap"
    for _ in range(3):
        eng.observe(Job(layout_kind(True, 0), tokens=100), 1.0e-2)
    assert eng.choose_compact(0) is False     # full side unmeasured next
    assert eng.decisions[-1]["why"] == "explore"
    for _ in range(3):
        eng.observe(Job(layout_kind(False, 0), tokens=100), 2.0e-2)
    assert eng.choose_compact(0) is True      # compact measured cheaper
    s = eng.decisions[-1]["scores"]
    assert s["compact"] < s["full"]
    # flip the measurements: full wins back
    eng2 = Engine()
    for _ in range(3):
        eng2.observe(Job(layout_kind(True, 0), tokens=100), 3.0e-2)
        eng2.observe(Job(layout_kind(False, 0), tokens=100), 1.0e-2)
    assert eng2.choose_compact(0) is False
    # re-explore: every 16th measured round runs the losing layout (the
    # assert above consumed round 1, so the 16th lands at picks[14])
    picks = [eng2.choose_compact(0) for _ in range(16)]
    assert picks[:14] == [False] * 14
    assert picks[14] is True
    assert any(d.get("why") == "re-explore"
               for d in list(eng2.decisions)[-16:])


def test_choose_serve_tick_aging_bounds_prefill_starvation():
    eng = Engine(max_prefill_defer=3)
    picks = [eng.choose_serve_tick(decode_slots=2, prefill_slots=1,
                                   prefill_tokens=64, decode_chunk=4,
                                   prefill_chunk=16) for _ in range(8)]
    assert picks[:3] == ["decode"] * 3           # min-FRT prefers decode
    assert picks[3] == "prefill"                 # aging bound fires
    assert eng.choose_serve_tick(0, 1, 64, 4, 16) == "prefill"
    assert eng.choose_serve_tick(2, 0, 0, 4, 16) == "decode"


# ------------------------------------------------------------- serve engine

@pytest.mark.slow
def test_serve_engine_matches_static_batched_server():
    """Chunked batched prefill + in-jit decode must reproduce the old
    one-token-per-dispatch server exactly (greedy)."""
    from repro.runtime.serve import BatchedServer
    cfg, params = _params()
    srv = BatchedServer(cfg, params, max_len=64)
    rng = np.random.default_rng(3)
    prompts = rng.integers(1, cfg.vocab, (4, 11)).astype(np.int32)
    ref = srv.generate_static(prompts, max_new=10, temperature=0.0)
    got = srv.generate(prompts, max_new=10, temperature=0.0)
    np.testing.assert_array_equal(got, ref)


@pytest.mark.slow
def test_serve_engine_continuous_join_evict_mixed_lengths():
    """More requests than slots, mixed prompt lengths: every request must
    finish with exactly max_new tokens, each matching a fresh static run."""
    from repro.runtime.serve import BatchedServer
    cfg, params = _params()
    eng = ServeEngine(cfg, params, max_len=64, slots=2, prefill_chunk=8,
                      decode_chunk=4)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, (l,)).astype(np.int32)
               for l in (3, 9, 14, 6, 9)]
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run_until_done()
    assert all(r.done.is_set() for r in reqs)
    assert eng.engine.jobs_run.get("serve_prefill", 0) >= 1
    srv = BatchedServer(cfg, params, max_len=64)
    for p, r in zip(prompts, reqs):
        ref = srv.generate_static(p[None, :], max_new=6, temperature=0.0)
        np.testing.assert_array_equal(r.output(), ref[0],
                                      err_msg=f"plen={len(p)}")


def test_serve_engine_inspect_and_update_between_ticks():
    cfg, params = _params()
    eng = ServeEngine(cfg, params, max_len=48, slots=2, prefill_chunk=4,
                      decode_chunk=2)
    eng.submit(np.arange(1, 5, dtype=np.int32), max_new=4)
    msg = eng.engine.controller.send(M.inspect())
    eng.tick()
    info = msg.wait(30)
    assert info["queue_depth"] == 1 or info["slots"]
    assert "engine" in info and "costs" in info["engine"]
    eng.engine.controller.send(M.update(max_prefill_defer=9))
    eng.tick()
    assert eng.engine.max_prefill_defer == 9
    eng.run_until_done()


def test_serve_engine_breakpoint_pauses_stream():
    cfg, params = _params()
    eng = ServeEngine(cfg, params, max_len=48, slots=2, prefill_chunk=4,
                      decode_chunk=2)
    eng.engine.controller.send(M.set_breakpoint(
        GlobalCountBreakpoint("tok-budget", "emitted", target=4)))
    eng.submit(np.arange(1, 5, dtype=np.int32), max_new=12)

    def resumer():
        while not eng.engine.controller.paused:
            time.sleep(0.02)
        eng.engine.controller.send(M.resume())

    th = threading.Thread(target=resumer)
    th.start()
    eng.run_until_done()
    th.join()
    assert "tok-budget" in eng.hit_breakpoints


def test_serve_engine_chunk_hot_update_never_strands_requests():
    """Raising the chunk sizes mid-stream beyond the headroom reserved at
    submit time must shrink the tick instead of stranding near-full slots."""
    cfg, params = _params()
    eng = ServeEngine(cfg, params, max_len=32, slots=2, prefill_chunk=8,
                      decode_chunk=4)
    reqs = [eng.submit(np.arange(1, 9, dtype=np.int32), max_new=12)
            for _ in range(2)]
    eng.tick()                                   # some progress at chunk 8
    eng.engine.controller.send(M.update(decode_chunk=64, prefill_chunk=64))
    eng.run_until_done()                         # must not raise / hang
    assert eng.decode_chunk == 64
    for r in reqs:
        assert r.done.is_set() and len(r.output()) == 12


def test_serve_generate_seed_reproducible_with_temperature():
    cfg, params = _params()
    eng = ServeEngine(cfg, params, max_len=48, slots=2, prefill_chunk=8,
                      decode_chunk=4)
    p = np.arange(1, 7, dtype=np.int32)[None, :]
    a = eng.generate(p, max_new=6, temperature=0.8, seed=7)
    b = eng.generate(p, max_new=6, temperature=0.8, seed=7)
    c = eng.generate(p, max_new=6, temperature=0.8, seed=8)
    np.testing.assert_array_equal(a, b)          # same seed -> same sample
    assert not np.array_equal(a, c)              # different seed -> differs


# ------------------------------------------------- loop as an engine client

def test_trainloop_is_engine_client():
    cfg = get_arch("gemma3-1b-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=8, global_batch=2)
    loop = TrainLoop(cfg, stream, TrainHyper(), LoopConfig(microbatches=2))
    assert loop.controller is loop.engine.controller
    loop.run(3)
    assert loop.engine.jobs_run.get("train_step_fused", 0) >= 1
    # warm-up skipped, later steps measured
    assert "train_step_fused" in loop.engine.costs.snapshot()
    info = loop._inspect("engine")
    assert info["engine"]["jobs_run"]["train_step_fused"] >= 1


def test_trainloop_shared_engine_across_train_and_serve():
    """One engine can own the control plane for both workload types — the
    unification the layer exists for."""
    cfg = get_arch("gemma3-1b-smoke")
    shared = Engine()
    stream = TokenStream(vocab=cfg.vocab, seq_len=8, global_batch=2)
    loop = TrainLoop(cfg, stream, TrainHyper(), LoopConfig(microbatches=1),
                     engine=shared)
    loop.run(2)
    serve = ServeEngine(cfg, loop.state["params"], max_len=48, slots=2,
                        prefill_chunk=4, decode_chunk=2, engine=shared)
    serve.submit(np.arange(1, 6, dtype=np.int32), max_new=3)
    serve.run_until_done()
    kinds = set(shared.jobs_run)
    assert {"train_step_fused", "serve_prefill"} <= kinds


# ------------------------------------------------------------ donation audit

def test_granulated_apply_migrate_donate_state():
    """The granulated-path apply/migrate jits donate the state: params AND
    optimizer-moment buffers are reused in place, so after the call the old
    state's leaves must be dead (jax 0.4.37 honors donation on CPU too —
    the live-buffer assertion runs everywhere)."""
    cfg = get_arch("olmoe-1b-7b-smoke")
    hyper = TrainHyper()
    _, apply, migrate = build_grad_step(cfg, hyper, donate=True)
    state = make_state(cfg, jax.random.PRNGKey(0))
    grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                         state["params"])
    state2, _ = apply(state, grads, 2, jnp.asarray(1.0))
    jax.block_until_ready(state2)
    assert all(x.is_deleted() for x in
               jax.tree.leaves(state["params"]) +
               jax.tree.leaves(state["opt"].m) +
               jax.tree.leaves(state["opt"].v)), \
        "apply must donate the incoming params/opt buffers"
    arr = jnp.asarray([[0, 0, 1]], jnp.int32)
    state3 = migrate(state2, arr)
    jax.block_until_ready(state3)
    assert all(x.is_deleted() for x in jax.tree.leaves(state2["params"])), \
        "migrate must donate the incoming state buffers"
    assert int(state3["step"]) == 1


def test_grad_step_default_donation_matches_backend():
    cfg = get_arch("gemma3-1b-smoke")
    # default wiring: donation on iff not CPU; just ensure both build & run
    _, apply, _ = build_grad_step(cfg, TrainHyper())
    state = make_state(cfg, jax.random.PRNGKey(1))
    grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                         state["params"])
    state2, _ = apply(state, grads, 1, jnp.asarray(1.0))
    assert int(state2["step"]) == 1
