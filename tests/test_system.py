"""End-to-end behaviour: the paper's full stack on the ML runtime —
training with Reshape expert-skew mitigation, Amber interactivity, Maestro
remat choice — loss goes down, skew goes down, nothing breaks."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import reduced
from repro.core.reshape_moe import MoEReshaper
from repro.core.skew import SkewParams
from repro.data.synthetic import TokenStream
from repro.optim.adamw import AdamWCfg
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.runtime.train import TrainHyper


@pytest.mark.slow
def test_train_loss_decreases():
    cfg = reduced(get_arch("paper-moe-100m"), layers=2, d_model=64,
                  vocab=256)
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    loop = TrainLoop(cfg, stream,
                     TrainHyper(opt=AdamWCfg(lr=3e-3, warmup_steps=5,
                                             total_steps=100)),
                     LoopConfig(microbatches=2))
    hist = loop.run(30)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


@pytest.mark.slow
def test_reshape_mitigation_live_in_training():
    """Skewed token classes -> routing hot spots; the reshaper must not
    increase drops, and must actually fire + change the plan."""
    cfg = get_arch("olmoe-1b-7b-smoke")     # 8 experts top-2, mesh-free
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))

    def run(reshaper):
        stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=8,
                             seed=5, class_alpha=2.0)
        loop = TrainLoop(cfg, stream, TrainHyper(),
                         LoopConfig(microbatches=1), reshaper=reshaper)
        hist = loop.run(12)
        drops = [h["dropped"].sum() for h in hist if "dropped" in h]
        return np.mean(drops[-4:]), loop

    base_drops, _ = run(None)
    rs = MoEReshaper(cfg, n_moe_layers=2, ep_ranks=2,
                     params=SkewParams(eta=0.0, tau=0.15), phase1_steps=1)
    mit_drops, loop = run(rs)
    assert rs.iterations > 0                 # mitigation actually fired
    assert mit_drops <= base_drops + 1       # result-awareness: fewer drops
    identity_cum = np.ones_like(loop.plan_cum)
    assert not np.array_equal(loop.plan_cum, identity_cum)  # plan changed


@pytest.mark.slow
def test_whisper_end_to_end_step():
    cfg = get_arch("whisper-base-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=16, global_batch=4)
    loop = TrainLoop(cfg, stream, TrainHyper(), LoopConfig(microbatches=2))
    hist = loop.run(2)
    assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])


def test_batched_serving():
    import jax
    from repro.models import lm
    from repro.runtime.serve import BatchedServer
    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, max_len=32)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, (3, 5)).astype(np.int32)
    out = srv.generate(prompts, max_new=4, temperature=0.0)
    assert out.shape == (3, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()
