"""Optimizer, compression, data-pipeline, and checkpointer tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.synthetic import TokenStream, tweets_like_rates, zipf_weights
from repro.optim import adamw
from repro.optim.compression import compress_tree, dequantize, quantize


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWCfg(lr=0.1, weight_decay=0.0, warmup_steps=1,
                         total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.apply(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    assert m["grad_norm"] > 0


def test_clip_norm():
    cfg = adamw.AdamWCfg(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)
    _, state, m = adamw.apply(params, {"w": jnp.asarray([100., 0., 0.])},
                              state, cfg)
    assert float(m["grad_norm"]) > 99


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWCfg(lr=1.0, warmup_steps=10, total_steps=100,
                         min_lr_frac=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.asarray(0)))
    lr9 = float(adamw.schedule(cfg, jnp.asarray(9)))
    lr_end = float(adamw.schedule(cfg, jnp.asarray(100)))
    assert lr0 < lr9 <= 1.0
    assert abs(lr_end - 0.1) < 1e-6


def test_quantize_roundtrip_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, a = quantize(g)
    back = dequantize(q, a)
    assert float(jnp.abs(back - g).max()) <= float(a) / 127.0 + 1e-6
    # error feedback: residual carries the lost mass
    tree, scales, res = compress_tree({"g": g}, {"g": jnp.zeros_like(g)})
    recon = dequantize(tree["g"], scales["g"]) + res["g"]
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g), atol=1e-5)


def test_token_stream_deterministic_and_restorable():
    s1 = TokenStream(vocab=1000, seq_len=8, global_batch=4, seed=7)
    b1 = [s1.next()["tokens"] for _ in range(3)]
    s2 = TokenStream(vocab=1000, seq_len=8, global_batch=4, seed=7)
    s2.next()
    state = s2.state()
    s3 = TokenStream(vocab=1000, seq_len=8, global_batch=4).restore(state)
    np.testing.assert_array_equal(b1[1], s3.next()["tokens"])
    np.testing.assert_array_equal(b1[2], s3.next()["tokens"])


def test_stream_class_skew_and_shift():
    s = TokenStream(vocab=800, seq_len=4, global_batch=400, seed=1,
                    n_classes=8, class_alpha=1.5, shift_at=2)
    c0 = np.bincount(s.next()["classes"], minlength=8)
    s.next()
    c2 = np.bincount(s.next()["classes"], minlength=8)
    assert c0.argmax() == 0                     # zipf-hot class 0
    assert c2.argmax() == 4                     # shifted by n/2


def test_zipf_and_tweets_rates():
    w = zipf_weights(10, 1.2)
    assert abs(w.sum() - 1) < 1e-9 and w[0] > w[-1]
    r = tweets_like_rates()
    assert r[6] > r[17] > r[4] > r[0]


def test_checkpointer_roundtrip_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    for step in (1, 2, 3):
        ck.save(step, state, [], {"note": step})
    assert ck.list_steps() == [2, 3]            # retention
    payload = ck.restore()
    assert payload["step"] == 3
    np.testing.assert_array_equal(payload["state"]["a"], np.arange(5))
