"""Async snapshot-then-persist checkpointing + live weight publishing.

Checkpoint side: the two-region split (blocking device->host ``snapshot``,
worker-thread host->disk ``persist``) must overlap the persist with the next
training step, and the durable-log barrier (fsync discipline + the ack
manifest) must guarantee recovery never sees a checkpoint the manifest does
not acknowledge — a crash anywhere inside persist falls back to the
previous acknowledged step and replays the control log from there (§2.6.2).

Serve side: ``ServeEngine.update(params=..., params_version=...)`` hot-swaps
target weights mid-stream with zero dropped requests; requests admitted
after the swap are bit-identical to a fresh engine started on the new
weights, the result cache never serves answers computed under old weights,
and placed pools' per-device-group params copies invalidate by source
identity on the next tick.
"""
import json
import os
import pickle
import threading
import time

import numpy as np
import pytest
import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch
from repro.core import messages as M
from repro.data.synthetic import TokenStream
from repro.engine.serve import ServeEngine
from repro.models import lm
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.runtime.serve import BatchedServer
from repro.runtime.train import TrainHyper

CFG = get_arch("gemma3-1b-smoke")
MAX_LEN = 64


def mk_loop(tmp, ckpt_every=0, ckpt_async=True, publish_every=0,
            publish_to=None):
    stream = TokenStream(vocab=CFG.vocab, seq_len=16, global_batch=4, seed=3)
    return TrainLoop(CFG, stream, TrainHyper(),
                     LoopConfig(microbatches=2, ckpt_every=ckpt_every,
                                ckpt_dir=tmp, ckpt_async=ckpt_async,
                                publish_every=publish_every),
                     publish_to=publish_to)


# --------------------------------------------------------- checkpointer unit

def test_list_steps_full_stem_parse(tmp_path):
    """Regression: steps >= 10**8 produce 9-digit filenames; the old fixed
    ``int(f[5:13])`` slice silently mis-parsed them, so latest-step
    selection and retention GC both misbehaved."""
    ck = Checkpointer(str(tmp_path), keep=3)
    big = 10**8
    for s in (7, big):
        ck.save(s, {"w": np.arange(3)})
    assert ck.list_steps() == [7, big]
    assert ck.latest_step() == big
    assert ck.restore()["step"] == big
    assert ck.restore(step=7)["step"] == 7


def test_snapshot_decouples_from_live_state(tmp_path):
    """The snapshot region's payload is a host copy: mutating device state
    afterwards (the next train step) must not leak into what persists."""
    ck = Checkpointer(str(tmp_path))
    state = {"w": np.arange(4.0)}
    payload = ck.snapshot(3, state)
    state["w"] += 100.0                 # "next step" mutates live state
    seen = []
    ck.persist_async(payload, on_done=seen.append)
    ck.wait()
    np.testing.assert_array_equal(ck.restore()["state"]["w"],
                                  np.arange(4.0))
    assert len(seen) == 1 and seen[0] > 0.0   # measured persist wall time


def test_wait_reraises_worker_error(tmp_path):
    ck = Checkpointer(str(tmp_path))
    payload = ck.snapshot(1, {"w": np.zeros(2)})
    ck.persist_async(payload)
    ck.wait()
    bad = dict(payload, step=2)
    ck.dir = str(tmp_path / "gone")     # worker-side failure: dir vanished
    ck.persist_async(bad)
    with pytest.raises(OSError):
        ck.wait()


def test_torn_tmp_write_is_invisible(tmp_path):
    """Crash mid-tmp-write: a partial ``.tmp`` file was never renamed, so
    restore never even considers it and returns the previous step."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": np.ones(2)})
    with open(ck._path(2) + ".tmp", "wb") as f:
        f.write(pickle.dumps({"step": 2})[:7])    # truncated mid-write
    assert ck.list_steps() == [1]
    assert ck.restore()["step"] == 1


def test_published_but_unacked_is_not_restorable(tmp_path):
    """Crash between the atomic rename and the manifest ack: the file is
    published but the durable log never acknowledged it, so recovery must
    conservatively fall back to the previous acknowledged step."""
    ck = Checkpointer(str(tmp_path))
    ck.save(2, {"w": np.ones(2) * 2})
    ck.save(4, {"w": np.ones(2) * 4})
    # simulate the crash point: step 4's ack line never made it to disk
    lines = open(ck._manifest()).read().splitlines()
    assert [json.loads(ln)["step"] for ln in lines] == [2, 4]
    with open(ck._manifest(), "w") as f:
        f.write(lines[0] + "\n")
    assert ck.list_steps() == [2, 4]          # both files published...
    assert ck.restorable_steps() == [2]       # ...but only 2 acknowledged
    assert ck.restore()["step"] == 2


def test_acked_but_corrupt_falls_back(tmp_path):
    """Byte-level corruption of an acknowledged file (despite the fsync
    discipline: disk trouble) must fall back to the next older readable
    checkpoint instead of raising."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": np.ones(2)})
    ck.save(2, {"w": np.ones(2) * 2})
    with open(ck._path(2), "wb") as f:
        f.write(b"\x80\x04corrupt")
    payload = ck.restore()
    assert payload["step"] == 1


def test_torn_manifest_line_skipped(tmp_path):
    """A torn trailing ack line (crash mid-ack-write) is not an ack."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": np.zeros(1)})
    ck.save(2, {"w": np.zeros(1)})
    with open(ck._manifest(), "a") as f:
        f.write('{"step": ')                      # torn line
    assert ck.restorable_steps() == [1, 2]
    assert ck.restore()["step"] == 2


def test_legacy_dir_without_manifest(tmp_path):
    """Pre-barrier directories (no MANIFEST.log) keep restoring: every
    published file is trusted, the old behavior."""
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"w": np.ones(3)})
    os.remove(ck._manifest())
    ck2 = Checkpointer(str(tmp_path))
    assert ck2.acked_steps() is None
    assert ck2.restore()["step"] == 5


# ----------------------------------------------------- persist/step overlap

def test_persist_overlaps_next_step(tmp_path):
    """The load-bearing overlap property: with ckpt_async the next training
    step runs while the persist worker is still writing.  The persist for
    the step-2 checkpoint is blocked on an event; the loop must still
    complete steps 3 and 4 before the persist is released."""
    loop = mk_loop(str(tmp_path), ckpt_every=2)
    started, release = threading.Event(), threading.Event()
    orig = Checkpointer.persist

    def gated_persist(self, payload):
        started.set()
        assert release.wait(30), "test driver never released the persist"
        return orig(self, payload)

    Checkpointer.persist = gated_persist
    try:
        th = threading.Thread(target=lambda: loop.run(4))
        th.start()
        assert started.wait(60), "persist never started"
        deadline = time.perf_counter() + 60
        while len(loop.history) < 4:          # steps 3,4 run DURING persist
            assert time.perf_counter() < deadline, \
                "next steps did not overlap the in-flight persist"
            time.sleep(0.01)
        release.set()
        th.join(60)
        assert not th.is_alive()
    finally:
        Checkpointer.persist = orig
        release.set()
    # both checkpoints landed durably by the time run() returned (wait())
    assert loop.ckpt.restorable_steps() == [2, 4]


def test_blocking_baseline_unchanged(tmp_path):
    """ckpt_async=False is the legacy blocking save: persisted inline,
    restorable immediately, same payload shape."""
    loop = mk_loop(str(tmp_path), ckpt_every=2, ckpt_async=False)
    loop.run(2)
    payload = loop.ckpt.restore()
    assert payload["step"] == 2
    assert payload["extra"]["lr_scale"] == 1.0


@pytest.mark.slow
def test_crash_mid_persist_recovers_previous_with_replay(tmp_path):
    """End-to-end durable-log barrier (§2.6.2): checkpoints at steps 2 and
    4 with an lr update logged at step 2; the crash lands between step 4's
    publish and its ack.  Recovery must come up at step 2 — never the
    unacknowledged step 4 — and replay the logged update at its recorded
    point, bit-identically to an uninterrupted run."""
    d = str(tmp_path / "a")
    ref = mk_loop(d, ckpt_every=2)
    ref.run(2)
    ref.controller.send(M.update(lr_scale=0.25))
    ref.run(2)
    ref_params = jax.tree.leaves(ref.state["params"])

    db = str(tmp_path / "b")
    loop = mk_loop(db, ckpt_every=2)
    loop.run(2)
    loop.controller.send(M.update(lr_scale=0.25))
    loop.run(2)
    del loop
    # crash point: step 4's ack line never hit the disk
    man = os.path.join(db, Checkpointer.MANIFEST)
    lines = open(man).read().splitlines()
    with open(man, "w") as f:
        f.write("\n".join(lines[:-1]) + "\n")

    stream = TokenStream(vocab=CFG.vocab, seq_len=16, global_batch=4, seed=3)
    rec = TrainLoop.recover(CFG, stream, TrainHyper(),
                            LoopConfig(microbatches=2, ckpt_every=2,
                                       ckpt_dir=db))
    assert int(rec.state["step"]) == 2
    rec.run(2)
    assert rec.lc.lr_scale == 0.25
    for a, b in zip(ref_params, jax.tree.leaves(rec.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ live weight publish

def _oracle(params, prompt, max_new):
    return BatchedServer(CFG, params, max_len=MAX_LEN).generate_static(
        np.asarray(prompt, np.int32)[None], max_new=max_new)[0]


def test_publish_zero_drop_mid_stream():
    """Hot weight swap with GENUINELY different weights: every in-flight
    request completes (zero drops), requests admitted after the swap are
    bit-identical to a fresh engine started on the new weights, and the
    result cache never serves answers computed under the old weights —
    neither a pre-swap stored answer nor a hybrid straddler's output."""
    p1 = lm.init(CFG, jax.random.PRNGKey(0))
    p2 = lm.init(CFG, jax.random.PRNGKey(1))
    rng = np.random.default_rng(7)
    eng = ServeEngine(CFG, p1, max_len=MAX_LEN, slots=2, prefill_chunk=4,
                      decode_chunk=2, prefix_cache=True)
    shared = rng.integers(1, CFG.vocab, (6,)).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, CFG.vocab, (l,)).astype(
                                   np.int32)]) for l in (3, 5, 2, 4)]
    # request 0 finishes pre-swap (its answer lands in the result cache);
    # request 1 straddles the swap (admitted old, finished new)
    done_pre = eng.submit(prompts[0], max_new=4)
    while not done_pre.done.is_set():
        assert eng.tick()
    np.testing.assert_array_equal(done_pre.output(),
                                  _oracle(p1, prompts[0], 4))
    straddler = eng.submit(prompts[1], max_new=12)
    for _ in range(2):                  # partially decoded under p1
        assert eng.tick()
    assert not straddler.done.is_set()
    eng.update(params=jax.tree.map(np.asarray, p2), params_version=1)
    post = [eng.submit(p, max_new=6) for p in prompts[2:]]
    # exact repeats of the pre-swap prompts: old-version cache entries and
    # hybrid outputs must NOT answer them under the new version
    repeat0 = eng.submit(prompts[0], max_new=4)
    repeat1 = eng.submit(prompts[1], max_new=12)
    ticks = 0
    while eng.queue or any(r is not None for r in eng.active):
        assert eng.tick() and ticks < 1000
        ticks += 1
    assert eng.params_version == 1
    # zero drops: every request, including the straddler, completed in full
    for r in (done_pre, straddler, repeat0, repeat1, *post):
        assert r.done.is_set() and len(r.tokens) >= r.max_new
    # post-swap admissions are bit-identical to a fresh engine on p2
    for p, r in zip(prompts[2:], post):
        np.testing.assert_array_equal(r.output(), _oracle(p2, p, 6))
    np.testing.assert_array_equal(repeat0.output(),
                                  _oracle(p2, prompts[0], 4))
    np.testing.assert_array_equal(repeat1.output(),
                                  _oracle(p2, prompts[1], 12))


def test_publish_invalidates_placed_pool_params():
    """A placed pool's per-device-group params copy re-commits on the first
    tick after a publish: the cache keys on source identity, and the swap
    rebinds ``eng.params`` to a fresh tree."""
    p1 = lm.init(CFG, jax.random.PRNGKey(0))
    p2 = lm.init(CFG, jax.random.PRNGKey(1))
    dev = jax.devices()[0]
    eng = ServeEngine(CFG, p1, max_len=MAX_LEN, slots=2, prefill_chunk=4,
                      decode_chunk=2, placements={0: [dev]})
    r1 = eng.submit(np.arange(1, 6, dtype=np.int32), max_new=3)
    while not r1.done.is_set():
        assert eng.tick()
    sp = eng.pools[0]
    ent = eng._pool_params[sp.devices()]
    old_src = ent["src"]
    assert old_src is eng.params
    eng.update(params=jax.tree.map(np.asarray, p2), params_version=1)
    prompt = np.arange(2, 9, dtype=np.int32)
    r2 = eng.submit(prompt, max_new=4)
    while not r2.done.is_set():
        assert eng.tick()
    ent = eng._pool_params[sp.devices()]
    assert ent["src"] is eng.params and ent["src"] is not old_src
    np.testing.assert_array_equal(r2.output(), _oracle(p2, prompt, 4))


def test_trainloop_publish_hook_end_to_end(tmp_path):
    """The full loop: TrainLoop(publish_to=ServeEngine, publish_every=2)
    pushes host params through the serve mailbox every 2 steps (reusing the
    checkpoint snapshot's host copy when steps align); the serve engine
    swaps at its next tick boundary and greedy outputs match a fresh engine
    on the trained weights."""
    serve = ServeEngine(CFG, lm.init(CFG, jax.random.PRNGKey(0)),
                        max_len=MAX_LEN, slots=2, prefill_chunk=4,
                        decode_chunk=2)
    loop = mk_loop(str(tmp_path), ckpt_every=2, publish_every=2,
                   publish_to=serve)
    loop.run(2)
    # the publish reused the step-2 checkpoint snapshot: one device sync
    assert loop._last_snapshot is not None
    assert loop._last_snapshot["step"] == 2
    prompt = np.arange(3, 10, dtype=np.int32)
    req = serve.submit(prompt, max_new=5)
    while not req.done.is_set():
        assert serve.tick()
    assert serve.params_version == 2
    trained = jax.tree.map(np.asarray, loop.state["params"])
    np.testing.assert_array_equal(req.output(), _oracle(trained, prompt, 5))
