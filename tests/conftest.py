import os
import sys

# smoke tests and benches must see exactly 1 device; ONLY dryrun.py sets the
# 512-device flag.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: slow end-to-end test; deselect with -m 'not slow' "
        "(fast suite targets < 60 s)")
