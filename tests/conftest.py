import os
import sys

# smoke tests and benches must see exactly 1 device; ONLY dryrun.py sets the
# 512-device flag.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
