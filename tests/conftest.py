import os
import sys

# smoke tests and benches must see exactly 1 device; ONLY dryrun.py sets the
# 512-device flag.  Exception: the CI multidevice job sets REPRO_MULTIDEVICE
# together with --xla_force_host_platform_device_count so the device-placed
# pool tests exercise real disjoint device groups.
if not os.environ.get("REPRO_MULTIDEVICE"):
    os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# One seed drives every randomized path in the suite: the numpy fixtures
# below (which feed the jnp sampling paths), and — via the @seed decorators
# in the hypothesis-based modules — the hypothesis example generator.  A CI
# failure is reproduced locally by exporting the same PYTEST_SEED; nothing
# randomized is allowed to fall back to wall-clock entropy.
PYTEST_SEED = int(os.environ.get("PYTEST_SEED", "0"))

try:  # hypothesis is a dev dependency (requirements-dev.txt), not a runtime one
    from hypothesis import HealthCheck, settings

    _suppress = [HealthCheck.too_slow, HealthCheck.data_too_large,
                 HealthCheck.filter_too_much]
    # "fast" is the tier-1 default: few examples, no deadline (jit compiles
    # blow any per-example deadline).  "slow" is the nightly/slow-job
    # profile: the differential harness widens its search.
    settings.register_profile("fast", max_examples=8, deadline=None,
                              suppress_health_check=_suppress)
    settings.register_profile("slow", max_examples=40, deadline=None,
                              suppress_health_check=_suppress)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "fast"))
except ImportError:  # pragma: no cover - hypothesis-less environments
    pass


@pytest.fixture(scope="session")
def suite_seed() -> int:
    """The suite-wide seed (PYTEST_SEED env var, default 0)."""
    return PYTEST_SEED


@pytest.fixture
def rng(suite_seed) -> np.random.Generator:
    """A fresh numpy Generator per test, pinned to PYTEST_SEED — use this
    instead of ad-hoc ``np.random.default_rng(<literal>)`` so one env var
    reproduces the whole suite's sampled inputs."""
    return np.random.default_rng(suite_seed)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: slow end-to-end test; deselect with -m 'not slow' "
        "(fast suite targets < 60 s)")
