"""State migration by mutability class (§3.5): replication for immutable
state, synchronized SBK moves for group-by, scattered-state merge for
range-sort under SBR."""
import numpy as np

from repro.core.state_migration import (GroupByAgg, HashJoinProbe,
                                        RangeSortWorker, is_mutable,
                                        merged_sorted_output, migration_time)


def test_mutability_table():
    assert not is_mutable("hashjoin", "probe")
    assert is_mutable("hashjoin", "build")
    assert is_mutable("groupby", "agg")
    assert is_mutable("sort", "insert")


def test_immutable_replication():
    a = HashJoinProbe({"k1": [1, 2], "k2": [3]})
    b = HashJoinProbe({})
    cost = a.replicate_to(b, ["k1"])
    assert b.build["k1"] == [1, 2]
    assert cost.bytes_moved == 16
    # probing at either worker gives identical results (immutable state)
    assert a.process("k1", 9) == b.process("k1", 9)


def test_groupby_sbk_migration_preserves_totals():
    a, b = GroupByAgg(), GroupByAgg()
    for v in range(10):
        a.process("g1", 1.0)
        a.process("g2", 2.0)
    a.migrate_keys_to(b, ["g2"])
    for v in range(5):
        b.process("g2", 2.0)
    assert a.agg.get("g2") is None
    assert b.agg["g2"] == 30.0           # 10*2 migrated + 5*2 new


def test_sort_scattered_state_merge():
    """Paper Fig 3.11: range [0,10] split between S1 (owner) and S3 (helper);
    on END markers the helper ships its scattered run back; global output
    must be perfectly sorted and complete."""
    rng = np.random.default_rng(0)
    s1, s2, s3 = (RangeSortWorker(i) for i in range(3))
    scopes = ["r0", "r1", "r2"]          # ranges [0,10], [11,20], [21,inf]
    owner = {"r0": s1, "r1": s2, "r2": s3}
    values = rng.integers(0, 30, 300)
    for i, v in enumerate(values):
        scope = "r0" if v <= 10 else "r1" if v <= 20 else "r2"
        w = owner[scope]
        if scope == "r0" and i % 2 == 0:
            w = s3                        # SBR: half of r0's records -> helper
        w.process(scope, int(v))
    # upstream END markers (2 upstream workers)
    for w in (s1, s2, s3):
        w.on_end_marker(0, 2, owner)
        w.on_end_marker(1, 2, owner)
    out = merged_sorted_output([s1, s2, s3], scopes)
    assert len(out) == len(values)
    assert out == sorted(values.tolist())
    # helper no longer holds scattered state
    assert "r0" not in s3.runs or not s3.runs["r0"]


def test_migration_time_model():
    assert migration_time(1000, 1000.0) == 1.1
