"""Integration tests of the paper's strategies on the pipelined simulator —
checks the qualitative claims of §3.7 hold on our implementation."""
import numpy as np

from repro.core.skew import SkewParams
from repro.core.strategies import (FlowJoinStrategy, FluxStrategy,
                                   NoMitigation, ReshapeStrategy)
from repro.core.transfer import PartitionLogic
from repro.core.worker import PipelinedSim
from repro.core.adaptive import TauAdjuster

KEYS = list(range(8))
RATES = {k: 1.0 for k in KEYS}
RATES[6] = 26.0
RATES[4] = 3.8


def run(strategy, ticks=300, **sim_kw):
    sim = PipelinedSim(8, lambda t: RATES, proc_rate=5.0,
                       logic=PartitionLogic.modulo(KEYS, 8), **sim_kw)
    sim.run(ticks, strategy, metric_interval=5)
    return sim


def pair_lb(sim):
    arr = sim.arrived
    other = max(a for i, a in enumerate(arr) if i != 6)
    return min(arr[6], other) / max(arr[6], other)


def test_reshape_beats_baselines_on_lb():
    lb_none = pair_lb(run(NoMitigation()))
    lb_flux = pair_lb(run(FluxStrategy(SkewParams(eta=20, tau=20))))
    lb_fj = pair_lb(run(FlowJoinStrategy()))
    lb_rs = pair_lb(run(ReshapeStrategy(SkewParams(eta=20, tau=20))))
    assert lb_rs > 0.85                         # paper: ~0.92
    assert lb_rs > lb_fj > lb_flux              # paper Fig 3.20 ordering
    assert lb_flux == lb_none                   # Flux can't split the hot key


def test_first_phase_reaches_representative_ratio_earlier():
    true_ratio = RATES[6] / RATES[4]

    def time_to_ratio(first_phase):
        hits = []

        def obs(sim):
            r = sim.processed_key[6] / max(sim.processed_key[4], 1.0)
            if abs(r - true_ratio) / true_ratio < 0.30 and not hits:
                hits.append(sim.tick_no)
        sim = PipelinedSim(8, lambda t: RATES, proc_rate=5.0,
                           logic=PartitionLogic.modulo(KEYS, 8))
        sim.run(600, ReshapeStrategy(SkewParams(eta=20, tau=20),
                                     first_phase=first_phase),
                metric_interval=5, observer=obs)
        return hits[0] if hits else 10_000
    t_with = time_to_ratio(True)
    t_without = time_to_ratio(False)
    assert t_with <= t_without                  # Fig 3.18/3.19


def test_control_delay_degrades_lb():
    lb_fast = pair_lb(run(ReshapeStrategy(SkewParams(eta=20, tau=20))))
    lb_slow = pair_lb(run(ReshapeStrategy(SkewParams(eta=20, tau=20)),
                          control_delay=30))
    assert lb_fast > lb_slow                    # Fig 3.21


def test_distribution_shift_iterative_beats_oneshot():
    # paper Fig 3.24: Flow-Join's one-shot split goes stale after the shift
    rates_a = {k: 1.0 for k in KEYS}
    rates_a[0] = 20.0
    rates_b = {k: 1.0 for k in KEYS}
    rates_b[0] = 8.0
    rates_b[1] = 13.0

    def mk():
        return PipelinedSim(8, lambda t: rates_a if t < 150 else rates_b,
                            proc_rate=4.0,
                            logic=PartitionLogic.modulo(KEYS, 8))
    rs = mk().run(400, ReshapeStrategy(SkewParams(eta=20, tau=20)),
                  metric_interval=5)
    fj = mk().run(400, FlowJoinStrategy(), metric_interval=5)

    def spread(sim):
        return np.std(sim.arrived)
    assert spread(rs) < spread(fj)


def test_adaptive_tau_reduces_iterations_for_tiny_tau():
    fixed = ReshapeStrategy(SkewParams(eta=20, tau=2))
    run(fixed)
    dyn = ReshapeStrategy(SkewParams(eta=20, tau=2),
                          adaptive_tau=TauAdjuster(eps_l=1.0, eps_u=5.0,
                                                   tau=2, increase_by=20))
    run(dyn)
    assert dyn.iterations <= fixed.iterations   # Fig 3.22


def test_migration_time_delays_mitigation_but_completes():
    sim = run(ReshapeStrategy(SkewParams(eta=20, tau=20)),
              migration_ticks=10)
    assert pair_lb(sim) > 0.6
