"""Reshape core: skew test, detection, transfer planning, adaptive tau,
estimator, helpers — unit-level paper-faithfulness checks."""
import math

import pytest

from repro.core.adaptive import TauAdjuster, tau_prime
from repro.core.estimator import MeanModelEstimator
from repro.core.helpers import choose_helpers, lr_max
from repro.core.skew import SkewParams, detect, load_balancing_ratio, skew_test
from repro.core.transfer import (PartitionLogic, phase1_apply, sbk_plan,
                                 sbr_apply, sbr_fraction)


def test_skew_test_eq_31_32():
    p = SkewParams(eta=100, tau=50)
    assert skew_test(200, 100, p)            # both inequalities hold
    assert not skew_test(90, 10, p)          # eta violated
    assert not skew_test(200, 180, p)        # tau violated


def test_detect_pairs_lowest_helper_first():
    p = SkewParams(eta=10, tau=10)
    loads = {0: 100.0, 1: 5.0, 2: 50.0, 3: 1.0}
    pairs = detect(loads, p)
    # most loaded worker gets the least loaded helper
    assert pairs[0] == (0, 3)
    # helper/skewed not reused
    flat = [w for pr in pairs for w in pr]
    assert len(flat) == len(set(flat))


def test_sbr_fraction_matches_paper_example():
    # §3.3.2: loads 26 vs 7 -> redirect 9.5/26 to equalize (16.5 each)
    f = sbr_fraction(26.0, 7.0)
    assert abs(f - 9.5 / 26.0) < 1e-9


def test_sbk_never_moves_hottest_key():
    logic = PartitionLogic.modulo(list(range(4)), 2)   # worker0: {0,2}
    loads = {0: 100.0, 2: 10.0}
    moved = sbk_plan(loads, 0, 1, logic, target=50.0)
    assert 0 not in moved                              # hottest key stays
    assert logic.assignment[2] == [(1, 1.0)]


def test_sbr_apply_routes_fraction():
    logic = PartitionLogic.modulo([0, 1], 2)
    sbr_apply(logic, 0, 1, 0.25)
    w = [logic.route(0, u / 100.0) for u in range(100)]
    assert abs(w.count(1) / 100.0 - 0.25) < 0.02


def test_phase1_redirects_everything():
    logic = PartitionLogic.modulo([0, 1], 2)
    phase1_apply(logic, 0, 1)
    assert all(logic.route(0, u / 10.0) == 1 for u in range(10))


def test_estimator_standard_error_formula():
    est = MeanModelEstimator()
    xs = [10.0, 12.0, 11.0, 13.0]
    for x in xs:
        est.add({0: x})
    mean, eps = est.predict(0)
    n = len(xs)
    mu = sum(xs) / n
    var = sum((x - mu) ** 2 for x in xs) / (n - 1)
    assert abs(mean - mu) < 1e-9
    assert abs(eps - math.sqrt(var) * math.sqrt(1 + 1 / n)) < 1e-9


def test_tau_adjuster_algorithm1():
    # gap >= tau, eps high -> increase
    adj = TauAdjuster(eps_l=98, eps_u=110, tau=100, increase_by=50)
    assert adj.adjust(300, 100, eps=200) == 150
    # gap < tau, eps low -> cut to current gap
    adj = TauAdjuster(eps_l=98, eps_u=110, tau=1000)
    assert adj.adjust(800, 100, eps=50) == 700
    # in-band -> unchanged
    adj = TauAdjuster(eps_l=98, eps_u=110, tau=500)
    assert adj.adjust(800, 100, eps=100) == 500


def test_tau_prime_earlier_start():
    # significant migration time M lowers the detection threshold
    assert tau_prime(100, 0.7, 0.3, tuples_per_sec=10, migration_secs=5) == \
        100 - 0.4 * 10 * 5


def test_choose_helpers_chi():
    # candidates in increasing load; migration grows with helper count
    cands = [(1, 0.05), (2, 0.10), (3, 0.15)]
    chosen = choose_helpers(
        f_s=0.5, candidates=cands, total_tuples=10000, tuples_left=3000,
        tuples_per_sec=100,
        migration_secs_for=lambda n: 4.0 * n)
    assert chosen  # at least one helper chosen
    # when LR_max is the binding term (plenty of future tuples), chi keeps
    # increasing with helper count -> all three chosen (paper Fig 3.13)
    all_chosen = choose_helpers(
        f_s=0.5, candidates=cands, total_tuples=10000, tuples_left=100000,
        tuples_per_sec=100, migration_secs_for=lambda n: 0.0)
    assert len(all_chosen) == 3


def test_lb_ratio():
    assert load_balancing_ratio([50, 100]) == 0.5
    assert load_balancing_ratio([0, 10]) == 0.0
