"""Fused MoE dispatch/combine kernel family vs the XLA reference.

Covers: fwd equivalence of the jnp fused algorithm AND the Pallas kernel in
interpret mode against ``models.moe.dispatch_combine`` (bit-identical drop
decisions / Reshape load metrics, allclose outputs), capacity-overflow drop
parity, a skewed-routing case exercising the Reshape metrics under a
non-identity SBR plan, gradient equivalence through the custom VJP, the
full-model wiring behind ``cfg.moe.fused_dispatch``, vmap (the serve decode
path), and the engine's CostBook-driven kernel selection.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.kernels.moe_dispatch import ops as dops
from repro.kernels.moe_dispatch.moe_dispatch import (combine_pallas,
                                                     dispatch_pallas)
from repro.kernels.moe_dispatch.ref import combine_ref, dispatch_ref
from repro.models import moe as moe_lib

RNG = np.random.default_rng(0)


def _case(t, d, k, s, skew=False, valid_frac=None):
    x = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    slot_np = RNG.integers(0, s, (t, k))
    if skew:
        slot_np[: t // 2, 0] = min(3, s - 1)     # hot slot -> forced drops
    slot = jnp.asarray(slot_np, jnp.int32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (t, k)), jnp.float32)
    valid = None if valid_frac is None else \
        jnp.asarray(RNG.random((t, k)) < valid_frac)
    return x, slot, w, valid


def _expert(buf):
    return jax.nn.silu(buf) * 1.5


# ------------------------------------------------------------ fwd equivalence

@pytest.mark.parametrize("t,d,k,s,cap", [(64, 16, 2, 10, 8),
                                         (48, 8, 4, 6, 4),     # heavy drops
                                         (37, 16, 2, 5, 16)])  # odd T
@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_dispatch_combine_matches_xla(t, d, k, s, cap, impl):
    for skew, vf in ((False, None), (True, None), (False, 0.7)):
        x, slot, w, valid = _case(t, d, k, s, skew, vf)
        y0, m0 = moe_lib.dispatch_combine(x, slot, w, _expert, s, cap,
                                          valid=valid)
        y1, m1 = dops.dispatch_combine(x, slot, w, _expert, s, cap,
                                       valid=valid, impl=impl)
        # drop decisions + Reshape load metrics are bit-identical
        for key in ("slot_counts", "kept_counts"):
            np.testing.assert_array_equal(np.asarray(m0[key]),
                                          np.asarray(m1[key]))
        assert int(m0["dropped"]) == int(m1["dropped"])
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=1e-5, rtol=1e-5)


def test_capacity_overflow_drop_parity():
    """Every assignment's keep/drop decision (not just the counts) matches
    the XLA path's stable-sort rank under forced capacity overflow."""
    t, d, k, s, cap = 96, 8, 4, 6, 5
    x, slot, w, _ = _case(t, d, k, s, skew=True)
    ones_w = jnp.ones((t, k), jnp.float32)
    ones_v = jnp.ones((t, k), jnp.int32)
    _, rank, keep, routed, kept = dops.dispatch(x, ones_w, slot, ones_v, s,
                                                cap, "jnp",
                                                dops.block_rows(t))
    # reference ranks via the baseline's stable argsort
    flat = np.asarray(slot).reshape(-1)
    sort_idx = np.argsort(flat, kind="stable")
    pos = np.empty_like(flat)
    seg = np.searchsorted(flat[sort_idx], np.arange(s + 1))
    pos[sort_idx] = np.arange(t * k) - seg[flat[sort_idx]]
    np.testing.assert_array_equal(np.asarray(rank).reshape(-1), pos)
    np.testing.assert_array_equal(np.asarray(keep).reshape(-1),
                                  (pos < cap).astype(np.int32))
    assert int(kept.sum()) < int(routed.sum())   # overflow really happened


def test_pallas_interpret_matches_ref_raw():
    """The Pallas kernels (interpret mode) against the jnp oracle at the
    raw dispatch/combine level, including the weighted-scatter operand."""
    t, d, k, s, cap = 64, 16, 3, 8, 9
    x, slot, wgt, _ = _case(t, d, k, s, skew=True)
    w = jnp.asarray(RNG.uniform(0.5, 2.0, (t, k)), jnp.float32)
    valid = jnp.asarray(RNG.random((t, k)) < 0.8).astype(jnp.int32)
    r0 = dispatch_ref(x, w, slot, valid, s, cap)
    r1 = dispatch_pallas(x, w, slot, valid, s, cap, bt=16)
    np.testing.assert_allclose(np.asarray(r0[0]), np.asarray(r1[0]),
                               atol=1e-5, rtol=1e-5)          # buf
    for a, b in zip(r0[1:], r1[1:]):                          # int outputs
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    buf, rank, keep = r0[0], r0[1], r0[2]
    y0 = combine_ref(buf, wgt, slot, rank, keep)
    y1 = combine_pallas(buf, wgt, slot, rank, keep, bt=16)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------ gradients

@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_grad_matches_xla(impl):
    t, d, k, s, cap = 48, 12, 2, 8, 7
    x, slot, w, valid = _case(t, d, k, s, skew=True, valid_frac=0.8)
    probe = jnp.cos(jnp.arange(d, dtype=jnp.float32))

    def loss_xla(x, w):
        y, _ = moe_lib.dispatch_combine(x, slot, w, _expert, s, cap,
                                        valid=valid)
        return (y * probe).sum()

    def loss_fused(x, w):
        y, _ = dops.dispatch_combine(x, slot, w, _expert, s, cap,
                                     valid=valid, impl=impl)
        return (y * probe).sum()

    g0 = jax.grad(loss_xla, (0, 1))(x, w)
    g1 = jax.jit(jax.grad(loss_fused, (0, 1)))(x, w)
    np.testing.assert_allclose(np.asarray(g0[0]), np.asarray(g1[0]),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g0[1]), np.asarray(g1[1]),
                               atol=1e-5, rtol=1e-4)
    assert float(jnp.abs(g1[0]).sum()) > 0      # grads actually flow


# ----------------------------------------------------------- model-level wire

def _skewed_batch(cfg, t=64):
    """Token batch whose embeddings drive a skewed router distribution."""
    toks = (np.arange(t) % 7).astype(np.int32).reshape(4, t // 4)
    return {"tokens": jnp.asarray(toks)}


def test_moe_ffn_fused_dispatch_matches():
    from repro.models import lm
    cfg = get_arch("olmoe-1b-7b-smoke")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    cfg_f = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, fused_dispatch=True))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    nl = lm.n_moe_layers(cfg)
    # non-identity SBR plan: expert 0 split across two slots (the Reshape
    # partitioning logic) so slot metrics differ from expert metrics
    plan = moe_lib.identity_plan(cfg, nl)
    slots = np.asarray(plan.slots).copy()
    cum = np.asarray(plan.cum).copy()
    spare = cfg.moe.num_experts          # first spare slot
    slots[:, 0, 1:] = spare
    cum[:, 0, 0] = 0.5
    batch = _skewed_batch(cfg)

    def fwd(c):
        return jax.jit(lambda p, b: lm.forward(
            p, b, c, plan=moe_lib.RoutingPlan(jnp.asarray(slots),
                                              jnp.asarray(cum))))(params,
                                                                  batch)

    l0, a0 = fwd(cfg)
    l1, a1 = fwd(cfg_f)
    # Reshape-visible load metrics bit-identical (incl. the replica split)
    for key in ("slot_counts", "kept_counts", "dropped", "expert_counts"):
        np.testing.assert_array_equal(np.asarray(a0["moe"][key]),
                                      np.asarray(a1["moe"][key]))
    assert int(np.asarray(a0["moe"]["dropped"]).sum()) > 0
    sc = np.asarray(a0["moe"]["slot_counts"])
    assert sc[:, spare].sum() > 0        # the replica slot really took load
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=2e-5,
                               rtol=1e-4)


def test_moe_ffn_fused_dispatch_grads_close():
    from repro.models import lm
    cfg = get_arch("olmoe-1b-7b-smoke")
    cfg_f = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, fused_dispatch=True))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    plan = moe_lib.identity_plan(cfg, lm.n_moe_layers(cfg))
    batch = _skewed_batch(cfg)

    def loss(p, c):
        lg, aux = lm.forward(p, batch, c, plan=plan)
        return (lg.astype(jnp.float32) ** 2).mean() + \
            aux["moe"]["aux_loss"].mean()

    g0 = jax.jit(lambda p: jax.grad(lambda q: loss(q, cfg))(p))(params)
    g1 = jax.jit(lambda p: jax.grad(lambda q: loss(q, cfg_f))(p))(params)
    # activations are bf16: the fused combine accumulates in f32 and rounds
    # once, where the XLA path scatter-adds in bf16 — bf16-ULP tolerance
    for (pth, a), b in zip(jax.tree_util.tree_flatten_with_path(g0)[0],
                           jax.tree.leaves(g1)):
        scale = max(float(jnp.abs(a).max()), 1e-3)
        assert float(jnp.abs(a - b).max()) <= 0.02 * scale, pth


def test_vmap_serve_decode_path():
    """dispatch_combine under vmap (the ServeEngine tick vmaps decode_step,
    which hits the fused path when cfg.moe.fused_dispatch is set)."""
    t, d, k, s, cap = 8, 8, 2, 6, 4
    xs = jnp.asarray(RNG.standard_normal((3, t, d)), jnp.float32)
    slots = jnp.asarray(RNG.integers(0, s, (3, t, k)), jnp.int32)
    ws = jnp.asarray(RNG.uniform(0.1, 1.0, (3, t, k)), jnp.float32)

    def one(x, slot, w, fused):
        return moe_lib.dispatch_combine(x, slot, w, _expert, s, cap,
                                        fused=fused)[0]

    y0 = jax.vmap(lambda x, sl, w: one(x, sl, w, False))(xs, slots, ws)
    y1 = jax.vmap(lambda x, sl, w: one(x, sl, w, True))(xs, slots, ws)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.slow
def test_fused_dispatch_training_matches():
    """End-to-end loss trajectory with fused gating + dispatch vs stock."""
    from repro.data.synthetic import TokenStream
    from repro.runtime.loop import LoopConfig, TrainLoop
    from repro.runtime.train import TrainHyper
    cfg = get_arch("olmoe-1b-7b-smoke")
    cfg_f = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, fused_gating=True,
                                     fused_dispatch=True))
    hists = []
    for c in (cfg, cfg_f):
        stream = TokenStream(vocab=c.vocab, seq_len=32, global_batch=8,
                             seed=5, class_alpha=2.0)
        loop = TrainLoop(c, stream, TrainHyper(),
                         LoopConfig(microbatches=2, step_path="fused"))
        hists.append(loop.run(3))
    # first step: same params -> routing and load metrics bit-identical
    a0, b0 = hists[0][0], hists[1][0]
    np.testing.assert_array_equal(a0["expert_counts"], b0["expert_counts"])
    np.testing.assert_array_equal(a0["slot_counts"], b0["slot_counts"])
    assert a0["dropped"].sum() == b0["dropped"].sum()
    # trajectories track within bf16-accumulation tolerance: the fused
    # combine sums in f32 and rounds once, the XLA path scatter-adds in
    # bf16, so activations (and hence later-step params) differ at ULP
    for a, b in zip(*hists):
        assert abs(a["loss"] - b["loss"]) < 5e-3


# --------------------------------------------------- CostBook kernel selection

def test_costbook_selects_dispatch_impl():
    """The engine explores both dispatch workflows, then picks per shape
    from measured costs — and flips when the measurements flip."""
    from repro.engine.engine import Engine
    from repro.engine.jobs import Job, dispatch_kind

    eng = Engine()
    # bootstrap: unmeasured fused arm is explored first
    assert eng.choose_dispatch_impl(1024) == "fused"
    eng.observe(Job(dispatch_kind("fused", 1024)), 0.010)   # cold, skipped
    assert eng.choose_dispatch_impl(1024) == "fused"
    eng.observe(Job(dispatch_kind("fused", 1024)), 0.010)
    # fused measured, xla not: explore the other arm
    assert eng.choose_dispatch_impl(1024) == "xla"
    eng.observe(Job(dispatch_kind("xla", 1024)), 0.030)     # cold, skipped
    eng.observe(Job(dispatch_kind("xla", 1024)), 0.030)
    d = eng.choose_dispatch_impl(1024)
    assert d == "fused"
    assert eng.decisions[-1]["scores"]["fused"] < \
        eng.decisions[-1]["scores"]["xla"]
    # per-shape: a different token count starts its own bootstrap
    assert eng.choose_dispatch_impl(4096) == "fused"
    assert eng.decisions[-1]["why"] == "bootstrap"
    # measurements flip at the big shape -> the choice flips too
    for _ in range(3):
        eng.observe(Job(dispatch_kind("fused", 4096)), 0.200)
        eng.observe(Job(dispatch_kind("xla", 4096)), 0.050)
    assert eng.choose_dispatch_impl(4096) == "xla"
    # forcing bypasses the cost model
    assert eng.choose_dispatch_impl(1024, forced="xla") == "xla"
    # periodic re-explore: the losing arm is re-run every 16th scored round
    # so a stale/poisoned EMA cannot wedge the choice forever
    choices = [eng.choose_dispatch_impl(4096) for _ in range(20)]
    assert "fused" in choices
    assert any(d.get("why") == "re-explore" for d in eng.decisions
               if d["decision"] == "dispatch_impl")


@pytest.mark.slow
def test_trainloop_dispatch_select_end_to_end():
    """TrainLoop under dispatch_select=auto: both impls get measured (first
    run per impl jit is cold and skipped), decisions are recorded, and the
    cost book ends up with per-shape entries for both workflows."""
    from repro.data.synthetic import TokenStream
    from repro.runtime.loop import LoopConfig, TrainLoop
    from repro.runtime.train import TrainHyper
    cfg = get_arch("olmoe-1b-7b-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=1)
    loop = TrainLoop(cfg, stream, TrainHyper(),
                     LoopConfig(microbatches=1, dispatch_select="auto"))
    loop.run(8)
    snap = loop.engine.costs.snapshot()
    assert any(k.startswith("moe_dispatch_fused:") for k in snap)
    assert any(k.startswith("moe_dispatch_xla:") for k in snap)
    dec = [d for d in loop.engine.decisions
           if d["decision"] == "dispatch_impl"]
    assert any("scores" in d for d in dec)       # reached the measured phase
    # the step-path decision stayed fused: impl exploration compiles fresh
    # jits, and those cold steps must not poison the step-path cost model
    assert all(d["choice"] == "fused" for d in loop.engine.decisions
               if d["decision"] == "step_path")
    assert len(loop.history) == 8


# -------------------------------------------------------- serve compact batch

def test_serve_compact_decode_matches():
    """Lane-waste flag: gathering active decode slots into a compact batch
    yields bit-identical outputs while >= half the pool idles."""
    from repro.engine.serve import ServeEngine
    from repro.models import lm
    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens, news = [4, 12, 20, 6], [20, 6, 12, 24]
    prompts = [rng.integers(1, cfg.vocab, (l,)).astype(np.int32)
               for l in lens]
    outs = {}
    for compact in (False, True):
        eng = ServeEngine(cfg, params, max_len=96, slots=8,
                          prefill_chunk=16, decode_chunk=4,
                          compact_decode=compact)
        reqs = [eng.submit(p, max_new=n) for p, n in zip(prompts, news)]
        eng.run_until_done()
        outs[compact] = [r.output() for r in reqs]
        if compact:
            assert eng.compact_ticks > 0
    for a, b in zip(outs[False], outs[True]):
        np.testing.assert_array_equal(a, b)
