"""Fused-step fast path + vectorized Reshape controller.

Covers: fused-vs-granulated step equivalence (same seed -> same steps, loss
trajectories within fp tolerance, identical Reshape plans/migrations),
adaptive control-granularity selection, device-plan caching, the unbiased
microbatch metric merge, the vectorized-vs-loop reshaper regression, the
fresh-SkewParams default, and the fused Pallas gating opt-in.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ArchConfig, MoECfg
from repro.core import messages as M
from repro.core import reshape_moe as rm
from repro.core.breakpoints import LocalBreakpoint
from repro.core.skew import SkewParams
from repro.data.synthetic import TokenStream
from repro.runtime.loop import (LoopConfig, TrainLoop, _finalize_metrics,
                                _merge_metrics)
from repro.runtime.train import TrainHyper


def _loop(cfg, step_path, reshaper=None, mb=2, seed=5, alpha=2.0):
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=8,
                         seed=seed, class_alpha=alpha)
    return TrainLoop(cfg, stream, TrainHyper(),
                     LoopConfig(microbatches=mb, step_path=step_path),
                     reshaper=reshaper)


def _reshaper(cfg):
    return rm.MoEReshaper(cfg, 2, ep_ranks=2,
                          params=SkewParams(eta=0.0, tau=0.15),
                          phase1_steps=1)


@pytest.mark.slow
def test_fused_matches_granulated_with_reshape():
    cfg = get_arch("olmoe-1b-7b-smoke")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    rs_f, rs_g = _reshaper(cfg), _reshaper(cfg)
    lf = _loop(cfg, "fused", rs_f)
    lg = _loop(cfg, "granulated", rs_g)
    hf, hg = lf.run(8), lg.run(8)
    assert int(lf.state["step"]) == int(lg.state["step"]) == 8
    for a, b in zip(hf, hg):
        assert abs(a["loss"] - b["loss"]) < 1e-4
        np.testing.assert_array_equal(a["expert_counts"], b["expert_counts"])
    # Reshape made identical decisions on both paths
    assert rs_f.iterations == rs_g.iterations > 0
    np.testing.assert_array_equal(lf.plan_slots, lg.plan_slots)
    np.testing.assert_array_equal(lf.plan_cum, lg.plan_cum)
    assert [(e.layer, e.hot_expert) for e in rs_f.events] == \
           [(e.layer, e.hot_expert) for e in rs_g.events]


def test_adaptive_granularity_selection():
    cfg = get_arch("gemma3-1b-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=8, global_batch=2)
    loop = TrainLoop(cfg, stream, TrainHyper(), LoopConfig(microbatches=1))
    assert loop._fused_eligible()                 # idle controller -> fused
    loop.local_bps.append(LocalBreakpoint("bp", lambda m: False))
    assert not loop._fused_eligible()             # breakpoint -> granulated
    loop.local_bps.clear()
    loop.controller.mailbox.put(M.inspect())
    assert not loop._fused_eligible()             # pending message
    loop.controller.mailbox.get_nowait()
    loop.controller.paused = True
    assert not loop._fused_eligible()             # paused
    loop.controller.paused = False
    loop.lc.step_path = "granulated"
    assert not loop._fused_eligible()             # forced off


def test_plan_cache_reuploads_only_on_change():
    cfg = get_arch("olmoe-1b-7b-smoke")
    loop = _loop(cfg, "auto")
    dev0 = loop._plan_args()
    # same values (fresh copies, as the reshaper returns) -> cache kept
    loop._set_plan(loop.plan_slots.copy(), loop.plan_cum.copy())
    assert loop._plan_args() is dev0
    # changed plan -> cache invalidated
    new_cum = loop.plan_cum.copy()
    new_cum[0, 0, 0] = 0.5
    loop._set_plan(loop.plan_slots.copy(), new_cum)
    dev1 = loop._plan_args()
    assert dev1 is not dev0
    assert float(dev1[1][0, 0, 0]) == 0.5


def test_merge_metrics_unbiased_mean():
    mbs = [{"loss": np.float32(v), "n": np.float32(1.0)}
           for v in (1.0, 2.0, 3.0, 4.0)]
    acc = {}
    for m in mbs:
        acc = _merge_metrics(acc, m)
    out = _finalize_metrics(acc, len(mbs))
    assert abs(out["loss"] - 2.5) < 1e-6          # old (a+b)/2 gave 3.125
    assert out["n"] == 4.0                        # non-mean keys still summed


def test_skewparams_default_not_shared():
    cfg = get_arch("olmoe-1b-7b-smoke")
    a = rm.MoEReshaper(cfg, 2, ep_ranks=2)
    default_tau = a.params.tau
    a.params.tau = 99.0                           # what _apply_updates does
    b = rm.MoEReshaper(cfg, 2, ep_ranks=2)
    assert b.params.tau == default_tau
    assert a.params is not b.params


# ------------------------------------------------ vectorized vs loop specs

def _mk_rs(cls, L=4, E=16, R=4, ranks=4, mode="sbr", seed=0):
    cfg = ArchConfig(name="t", family="moe", num_layers=L, d_model=64,
                     n_heads=2, n_kv_heads=2, d_ff=256, vocab=256,
                     moe=MoECfg(num_experts=E, top_k=2, expert_d_ff=256,
                                max_replicas=R))
    return cls(cfg, L, ep_ranks=ranks,
               params=SkewParams(eta=0.0, tau=0.1), phase1_steps=1,
               mode=mode)


def _randomize(rs, rng, steps=3):
    """Drive real mitigation steps so plans leave the identity state."""
    L, E = rs.nl, rs.cfg.moe.num_experts
    for _ in range(steps):
        counts = rng.gamma(1.0, 100.0, (L, E)) + np.eye(L, E) * 5000
        rs.observe(counts, rng.integers(0, 50, L))
        rs.step()


def test_vectorized_methods_match_loop_refs():
    rng = np.random.default_rng(0)
    for (L, E, R, ranks) in [(2, 8, 4, 2), (4, 16, 2, 4), (8, 32, 4, 8)]:
        rs = _mk_rs(rm.MoEReshaper, L, E, R, ranks)
        _randomize(rs, rng)
        for l in range(L):
            # rank_loads: the loop spec computed fracs in f32 (see
            # reference docstring) -> f32-level tolerance
            np.testing.assert_allclose(
                rs.rank_loads(l), rm.rank_loads_loop(rs, l), rtol=1e-6)
            for e in range(E):
                assert abs(rs._current_frac(l, e) -
                           rm.current_frac_loop(rs, l, e)) < 1e-9
        np.testing.assert_allclose(
            rs.rank_loads_all(),
            np.stack([rm.rank_loads_loop(rs, l) for l in range(L)]),
            rtol=1e-6)
        # waterfill: vectorized write == loop-reference row
        loads = rs.rank_loads(0)
        hot = int(np.argmax(rs._ema_expert[0]))
        helpers = [h for h in range(ranks)
                   if h != rs.layout.rank_of_expert(hot)][:R - 1]
        if helpers:
            ref_slots, ref_cum = rm.waterfill_row_loop(
                rs, 0, hot, helpers, loads, boost=1.3)
            rs._waterfill(0, hot, helpers, loads, boost=1.3)
            np.testing.assert_array_equal(rs.plan_slots[0, hot], ref_slots)
            np.testing.assert_array_equal(rs.plan_cum[0, hot], ref_cum)


@pytest.mark.parametrize("mode", ["sbr", "sbk"])
def test_full_step_decisions_match_loop_reshaper(mode):
    """The restructured/batched step() must make bit-identical decisions to
    the pre-vectorization sequential implementation (LoopReshaper)."""
    rng = np.random.default_rng(1)
    vec = _mk_rs(rm.MoEReshaper, 8, 32, 4, 8, mode)
    ref = _mk_rs(rm.LoopReshaper, 8, 32, 4, 8, mode)
    for _ in range(8):
        counts = rng.gamma(1.0, 100.0, (8, 32)) + np.eye(8, 32) * 4000
        dropped = rng.integers(0, 50, 8)
        vec.observe(counts, dropped)
        ref.observe(counts, dropped)
        ps_v, pc_v, mig_v = vec.step()
        ps_r, pc_r, mig_r = ref.step()
        np.testing.assert_array_equal(ps_v, ps_r)
        np.testing.assert_array_equal(pc_v, pc_r)
        assert [(m.layer, m.src_slot, m.dst_slot) for m in mig_v] == \
               [(m.layer, m.src_slot, m.dst_slot) for m in mig_r]
    assert vec.active == ref.active
    assert vec.spare_owner == ref.spare_owner
    np.testing.assert_array_equal(vec.backlog, ref.backlog)
    assert [(e.layer, e.hot_expert, e.fraction, e.phase)
            for e in vec.events] == \
           [(e.layer, e.hot_expert, e.fraction, e.phase)
            for e in ref.events]


# ------------------------------------------------------- fused gating path

def test_fused_gating_route_matches_topk():
    import jax
    import jax.numpy as jnp
    from repro.models import moe as moe_lib
    cfg = get_arch("olmoe-1b-7b-smoke")
    cfg_f = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, fused_gating=True))
    rng = np.random.default_rng(0)
    t, dm = 64, cfg.d_model
    x = jnp.asarray(rng.standard_normal((t, dm)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((dm, cfg.moe.num_experts)) * 0.1,
                    jnp.float32)
    plan = moe_lib.identity_plan(cfg, 1)
    s0, w0, p0, e0, c0 = moe_lib.route(w, x, plan.slots[0], plan.cum[0],
                                       cfg)
    s1, w1, p1, e1, c1 = moe_lib.route(w, x, plan.slots[0], plan.cum[0],
                                       cfg_f)
    assert c0 is None and c1 is not None
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_allclose(np.asarray(w0), np.asarray(w1), atol=1e-6)
    # the kernel's free histogram == scatter-add over chosen experts
    hist = np.zeros(cfg.moe.num_experts, np.int32)
    np.add.at(hist, np.asarray(e1).reshape(-1), 1)
    np.testing.assert_array_equal(np.asarray(c1), hist)

    # gradients flow to the router through the probs re-gather
    def loss(wr):
        _, wt, probs, _, _ = moe_lib.route(wr, x, plan.slots[0],
                                           plan.cum[0], cfg_f)
        return (wt.sum() + probs.sum())
    g = jax.grad(loss)(w)
    assert float(jnp.abs(g).sum()) > 0


@pytest.mark.slow
def test_fused_gating_training_matches():
    cfg = get_arch("olmoe-1b-7b-smoke")
    cfg_f = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, fused_gating=True))
    h0 = _loop(cfg, "fused", mb=2, alpha=0.0).run(3)
    h1 = _loop(cfg_f, "fused", mb=2, alpha=0.0).run(3)
    for a, b in zip(h0, h1):
        assert abs(a["loss"] - b["loss"]) < 1e-4
        np.testing.assert_array_equal(a["expert_counts"],
                                      b["expert_counts"])
