"""Per-arch smoke tests: reduced config of the same family, one forward and
one train step on CPU, asserting shapes + finiteness; plus decode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import lm
from repro.runtime.train import TrainHyper, build_train_step, make_state
from repro.configs.base import ShapeCfg


def _batch(cfg, b, s):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.enc_layers:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, 3))
        batch["positions3"] = pos.astype(jnp.int32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch):
    cfg = get_arch(arch + "-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    logits, aux = lm.forward(params, _batch(cfg, b, s), cfg)
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    state = lm.init_cache(cfg, b, 32)
    lg, state = lm.decode_step(params, state,
                               jnp.ones((b, 1), jnp.int32), cfg)
    assert lg.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()
    assert int(state["pos"]) == 1


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_arch(arch + "-smoke")
    shape = ShapeCfg("t", 16, 4, "train", microbatches=2)
    state = make_state(cfg, jax.random.PRNGKey(1))
    step = build_train_step(cfg, shape, TrainHyper())
    batch = _batch(cfg, shape.global_batch, shape.seq_len)
    nl = lm.n_moe_layers(cfg)
    if nl:
        from repro.models.moe import identity_plan
        plan = identity_plan(cfg, nl)
        ps, pc = plan.slots, plan.cum
    else:
        ps = jnp.zeros((1, 1, 1), jnp.int32)
        pc = jnp.ones((1, 1, 1), jnp.float32)
    new_state, metrics = jax.jit(step)(state, batch, ps, pc)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))), jax.tree.map(
            lambda a, b_: a - b_, new_state["params"], state["params"]), 0.0)
    assert delta > 0
