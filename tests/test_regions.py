"""Maestro: regions, region graph, cycle repair, materialization
enumeration, result-aware FRT choice (paper Ch. 4)."""
import pytest

from repro.core.materialization import conflicts, enumerate_choices
from repro.core.regions import (Op, Workflow, is_schedulable, region_graph,
                                regions, schedule)
from repro.core.scheduler import (CostModel, cardinalities, choose,
                                  first_response_time, materialized_bytes,
                                  remat_policy)


def fig41() -> Workflow:
    """Scan -> (F1 -> Join.build[blocking], F2 -> Join.probe) -> Sink."""
    wf = Workflow()
    for op in [Op("scan", "scan", 1.0, 1.0, 1000),
               Op("f1", "filter", 1.0, 0.5), Op("f2", "filter", 1.0, 0.5),
               Op("join", "join", 2.0, 1.0), Op("sink", "sink", 0.1, 1.0)]:
        wf.add_op(op)
    wf.add_edge("scan", "f1").add_edge("scan", "f2")
    wf.add_edge("f1", "join", blocking=True, port="build")
    wf.add_edge("f2", "join", port="probe")
    wf.add_edge("join", "sink")
    return wf


def chain() -> Workflow:
    wf = Workflow()
    for op in [Op("scan", "scan", 1.0, 1.0, 100),
               Op("sort", "sort", 1.0, 1.0), Op("sink", "sink", 0.1)]:
        wf.add_op(op)
    wf.add_edge("scan", "sort", blocking=True)
    wf.add_edge("sort", "sink")
    return wf


def test_regions_split_at_blocking_edges():
    wf = chain()
    regs = regions(wf)
    assert len(regs) == 2
    assert is_schedulable(wf)
    order = schedule(wf)
    assert "scan" in order[0] and "sink" in order[1]


def test_fig41_unschedulable_until_materialized():
    wf = fig41()
    assert not is_schedulable(wf)
    confs = conflicts(wf)
    assert len(confs) == 1
    choices = enumerate_choices(wf)
    # the two choices discussed in §4.1: scan->f2 (AsterixDB heuristic)
    # and f2->join
    assert frozenset({("scan", "f2")}) in choices
    assert frozenset({("f2", "join")}) in choices
    for c in choices:
        assert is_schedulable(wf.materialize(c))


def test_result_aware_choice_minimizes_frt():
    wf = fig41()
    cm = CostModel()
    best, info = choose(wf, cm)
    frts = {tuple(sorted(c)): f for f, b, c in info["all"]}
    assert first_response_time(wf, best, cm) == min(
        first_response_time(wf, c, cm) for c in enumerate_choices(wf))
    # the min-FRT choice here keeps f2's work pipelined with the sink
    assert best == frozenset({("scan", "f2")})
    # and it pays more materialized bytes — the paper's trade-off
    assert materialized_bytes(wf, best, cm) > materialized_bytes(
        wf, frozenset({("f2", "join")}), cm)


def test_two_join_workflow_choice_product():
    """Fig 4.11-style: two joins each with a replicated source conflict."""
    wf = Workflow()
    for name, kind, cost, sel, card in [
            ("s", "scan", 1, 1, 1000), ("d1", "replicate", 0.1, 2, 0),
            ("f", "filter", 1, 0.5, 0), ("j1", "join", 2, 1, 0),
            ("d2", "replicate", 0.1, 2, 0), ("m", "ml", 5, 1, 0),
            ("j2", "join", 2, 1, 0), ("sink", "sink", 0.1, 1, 0)]:
        wf.add_op(Op(name, kind, cost, sel, card))
    wf.add_edge("s", "d1")
    wf.add_edge("d1", "f").add_edge("d1", "j1", blocking=True, port="build")
    wf.add_edge("f", "j1", port="probe")
    wf.add_edge("j1", "d2")
    wf.add_edge("d2", "m").add_edge("d2", "j2", blocking=True, port="build")
    wf.add_edge("m", "j2", port="probe")
    wf.add_edge("j2", "sink")
    assert not is_schedulable(wf)
    choices = enumerate_choices(wf)
    assert len(choices) >= 4            # >=2 cuts per conflict, cross product
    for c in choices:
        assert is_schedulable(wf.materialize(c))
    best, info = choose(wf, CostModel())
    assert is_schedulable(wf.materialize(best))


def test_cardinality_propagation():
    wf = fig41()
    cards = cardinalities(wf)
    assert cards["scan"] == 1000
    assert cards["f1"] == 500
    assert cards["join"] == 1000        # sel 1.0 * (500 + 500)


def test_remat_policy_result_aware():
    from repro.configs import get_arch
    cfg = get_arch("yi-34b")
    # tight memory -> full remat chosen; loose -> none
    tight, _ = remat_policy(cfg, None, hbm_bytes_per_device=1e9,
                            act_bytes_per_layer={"none": 1e9, "dots": 1e8,
                                                 "full": 1e6},
                            step_flops=1e15, peak_flops=2e14)
    assert tight == "full"
    loose, _ = remat_policy(cfg, None, hbm_bytes_per_device=1e12,
                            act_bytes_per_layer={"none": 1e9, "dots": 1e8,
                                                 "full": 1e6},
                            step_flops=1e15, peak_flops=2e14)
    assert loose == "none"
