"""Differential serve-equivalence harness.

The hand-picked equivalence tests in test_engine.py / test_serve_consistency
pin a few (pool, chunk, prompt) shapes; this harness sweeps the scenario
space — random prompt lengths and response budgets, pool sizes, chunk sizes,
join/evict pressure (more requests than slots), control-message
interleavings delivered between ticks, and hot config updates — and asserts
that ``ServeEngine`` greedy outputs are **bit-identical** to the static
``BatchedServer.generate_static`` oracle across ``compact_decode`` ×
``spec_decode`` × ``proposer/draft`` × ``prefix_cache`` × ``pools`` ×
``placements``/mid-stream ``drain_pool`` (device-placed pools + live slot
migration; same-device meshes on a 1-device host, disjoint halves under the
CI multidevice job) (scenarios mix a shared
prompt preamble in so the prefix-cache axis exercises seeded admissions
and result-cache hits, not just the miss path; multi-pool runs take the weighted-FRT
``choose_serve_job`` arbitration; the priority-class-specific paths are
pinned separately in tests/test_serve_priority.py).  Speculative decode makes this the load-bearing test: its
acceptance mask must commit exactly the tokens plain greedy decode would
have produced, under every join/evict/control interleaving.

Two layers share one scenario generator (seeded from ``PYTEST_SEED``):

* ``test_differential_seeded`` — a fixed number of generated scenarios,
  pure numpy, always runs (tier-1 keeps coverage even where hypothesis is
  not installed); a wider batch rides the ``slow`` mark.
* ``test_differential_hypothesis`` — the same runner driven by hypothesis
  strategies (shrinking!), ``fast`` profile in the tier-1 CI job, widened
  by ``HYPOTHESIS_PROFILE=slow`` in the slow job.
"""
from functools import lru_cache

import numpy as np
import jax
import pytest

from repro.configs import get_arch
from repro.core import messages as M
from repro.engine.loadgen import arrival_offsets
from repro.engine.serve import ServeEngine
from repro.models import lm
from repro.runtime.serve import BatchedServer

from conftest import PYTEST_SEED

CFG = get_arch("gemma3-1b-smoke")
MAX_LEN = 64
# bounded dims keep the tick-jit specialization count (and thus compile
# time) shared and small across the whole sweep; build_slot_tick memoizes
# per config, so every scenario reuses the same compiled ticks
SLOTS = (1, 2, 3)
PREFILL_CHUNKS = (1, 2, 4, 8)
DECODE_CHUNKS = (1, 2, 4)
CTL_KINDS = ("pause_batch", "update_chunks", "toggle_spec", "update_draft",
             "publish_params")
# draft-proposer axis: no draft / truncated self-draft (random-init, so its
# acceptance is ~0 — the all-reject path) / the target itself as draft
# (acceptance ~1 — the max-commit path).  Both ends must be bit-identical.
DRAFTS = (None, "self", "target")


@lru_cache(maxsize=None)
def _fixture():
    params = lm.init(CFG, jax.random.PRNGKey(0))
    return params, BatchedServer(CFG, params, max_len=MAX_LEN)


_ORACLE = {}


def oracle(prompt, max_new):
    """Static-loop greedy reference, memoized — repeated scenarios hit the
    same prompts and the static path costs one dispatch per token."""
    key = (tuple(int(t) for t in prompt), int(max_new))
    if key not in _ORACLE:
        _, srv = _fixture()
        _ORACLE[key] = srv.generate_static(
            np.asarray(prompt, np.int32)[None], max_new=int(max_new))[0]
    return _ORACLE[key]


def _ctl_batch(eng, kind, rng):
    """Deliver one control batch into the mailbox.  A pause is always
    accompanied by a resume in the same batch — the engine's poll blocks
    while paused, so an unpaired pause would deadlock the single-threaded
    driver (the threaded pause path is covered in test_serve_consistency)."""
    ctl = eng.engine.controller
    if kind == "pause_batch":
        ctl.send(M.pause())
        ctl.send(M.inspect())
        ctl.send(M.update(max_prefill_defer=int(rng.integers(1, 8))))
        ctl.send(M.resume())
    elif kind == "update_chunks":
        ctl.send(M.update(decode_chunk=int(rng.choice(DECODE_CHUNKS)),
                          prefill_chunk=int(rng.choice(PREFILL_CHUNKS))))
    elif kind == "toggle_spec":
        ctl.send(M.update(spec_decode=bool(rng.integers(2))))
    elif kind == "update_draft":
        # hot draft republish mid-stream, with deliberately *garbage*
        # weights: a draft can only change acceptance, never outputs.
        # (On draft-free engines the update is a silent no-op.)
        if eng.draft_params is not None:
            ctl.send(M.update(draft_params=jax.tree.map(
                lambda x: -x, eng.draft_params)))
        else:
            ctl.send(M.update(draft_params=None))
    elif kind == "publish_params":
        # mid-stream weight publish with VALUE-identical params under a
        # fresh object identity + version bump: exercises every hot-swap
        # invalidation path (_params_for identity cache, prefix-tree
        # version flush, result-cache re-keying, joined_version gating of
        # stores) while outputs stay oracle-comparable — genuinely new
        # weights are covered in tests/test_async_checkpoint.py
        ctl.send(M.update(params=jax.tree.map(lambda x: x, eng.params),
                          params_version=eng.params_version + 1))


def _gen_prompts(rng, n_req):
    """Random prompts, with a scenario-level shared preamble mixed in so
    the prefix-cache axis actually exercises seeded admissions (fully
    disjoint random prompts would never produce a radix hit)."""
    shared = rng.integers(1, CFG.vocab,
                          int(rng.integers(0, 9))).astype(np.int32)
    prompts = []
    for _ in range(n_req):
        tail = rng.integers(1, CFG.vocab,
                            int(rng.integers(1, 13))).astype(np.int32)
        prompts.append(np.concatenate([shared, tail])
                       if shared.size and rng.integers(2) else tail)
    return prompts


def _gen_arrivals(rng, n_req):
    """Draw a request-arrival pattern from the loadgen samplers (bounded
    to a small tick window so scenarios still drain fast)."""
    kind = str(rng.choice(["closed", "poisson", "bursty"]))
    if kind == "closed":
        return None
    if kind == "poisson":
        at = arrival_offsets("poisson", n_req, rng, rate=0.7)
    else:
        at = arrival_offsets("bursty", n_req, rng, burst=2, gap=3.0)
    return [int(t) for t in np.minimum(at, 12)]


def gen_scenario(rng):
    n_req = int(rng.integers(1, 6))
    return {
        "prompts": _gen_prompts(rng, n_req),
        "max_news": [int(rng.integers(1, 9)) for _ in range(n_req)],
        "slots": int(rng.choice(SLOTS)),
        "prefill_chunk": int(rng.choice(PREFILL_CHUNKS)),
        "decode_chunk": int(rng.choice(DECODE_CHUNKS)),
        "compact": bool(rng.integers(2)),
        "spec": bool(rng.integers(2)),
        "draft": DRAFTS[int(rng.integers(len(DRAFTS)))],
        # cross-request prefix cache + result cache: seeded admissions and
        # exact-hit answers must leave greedy outputs bit-identical
        "prefix_cache": bool(rng.integers(2)),
        # 1 pool -> the legacy single-pool decision path; 2 pools -> the
        # weighted multi-pool arbitration.  Pool slot counts stay inside
        # SLOTS, so no new tick-jit specializations enter the sweep.
        "pools": int(rng.integers(1, 3)),
        # device-placed pools: params/caches committed to per-pool meshes
        # (disjoint halves on a multi-device host, same-device meshes on
        # one) — the placement-adjusted arbitration and the parallel
        # group-tick path must stay bit-identical
        "placements": bool(rng.integers(2)),
        # mid-stream elastic scale-in: drain pool 0 at this tick (ignored
        # on single-pool scenarios) — live slot migration under whatever
        # spec/draft/prefix axes the scenario drew
        "drain_at": int(rng.integers(0, 7)) if rng.integers(2) else None,
        # 0..2 control batches at distinct tick indices
        "schedule": {int(t): str(rng.choice(CTL_KINDS))
                     for t in rng.choice(7, size=int(rng.integers(0, 3)),
                                         replace=False)},
        "ctl_seed": int(rng.integers(0, 2**31)),
        # loadgen-driven arrival axis: per-request submit offsets in ticks
        # (None: the historical submit-everything-up-front scenario).
        # Staggered joins hit admission/aging mid-stream instead of only
        # at tick 0 — outputs must stay oracle-identical regardless.
        "arrival": _gen_arrivals(rng, n_req),
    }


def _draft_kwargs(sc, params):
    d = sc.get("draft")
    if d == "self":
        return {"draft": "self"}
    if d == "target":
        # the target as its own draft: max-acceptance end of the axis
        return {"draft_cfg": CFG, "draft_params": params}
    return {}


def _placements(sc):
    """Per-pool meshes for placed scenarios: disjoint device halves when
    the host has several devices, same-device meshes on one — either way
    the placed code paths (committed params/caches, sharded tick jits,
    migration transfers) run."""
    if not sc.get("placements") or sc.get("pools", 1) < 2:
        return None
    devs = jax.devices()
    half = max(len(devs) // 2, 1)
    return {0: devs[:half], 1: devs[half:] or devs}


def run_scenario(sc):
    params, _ = _fixture()
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=sc["slots"],
                      prefill_chunk=sc["prefill_chunk"],
                      decode_chunk=sc["decode_chunk"],
                      compact_decode=sc["compact"],
                      spec_decode=sc["spec"], pools=sc.get("pools", 1),
                      prefix_cache=sc.get("prefix_cache", False),
                      placements=_placements(sc),
                      **_draft_kwargs(sc, params))
    arrival = sc.get("arrival") or [0] * len(sc["prompts"])
    # submit in arrival order; pending requests join at their offset tick
    pend = sorted(range(len(sc["prompts"])), key=lambda i: arrival[i])
    reqs: list = [None] * len(sc["prompts"])
    ctl_rng = np.random.default_rng(sc["ctl_seed"])
    drain_at = sc.get("drain_at")
    ticks = 0
    while pend or eng.queue or any(r is not None for r in eng.active):
        while pend and arrival[pend[0]] <= ticks:
            i = pend.pop(0)
            reqs[i] = eng.submit(sc["prompts"][i], max_new=sc["max_news"][i])
        if ticks in sc["schedule"]:
            _ctl_batch(eng, sc["schedule"][ticks], ctl_rng)
        if ticks == drain_at and len(eng.pools) > 1:
            # elastic scale-in mid-stream: every in-flight slot of pool 0
            # migrates (or finishes in place under saturation) and the
            # outputs below must still match the oracle bit for bit
            eng.drain_pool(eng.pools[0].lid)
        assert eng.tick(), "engine stopped unexpectedly"
        ticks += 1
        assert ticks < 1000, "serve engine did not drain"
    for i, (p, n, r) in enumerate(zip(sc["prompts"], sc["max_news"], reqs)):
        assert r.done.is_set()
        np.testing.assert_array_equal(
            r.output(), oracle(p, n),
            err_msg=(f"req {i}: plen={len(p)} max_new={n} slots={sc['slots']}"
                     f" pc={sc['prefill_chunk']} dc={sc['decode_chunk']}"
                     f" compact={sc['compact']} spec={sc['spec']}"
                     f" draft={sc.get('draft')}"
                     f" pools={sc.get('pools', 1)}"
                     f" prefix_cache={sc.get('prefix_cache', False)}"
                     f" placements={sc.get('placements', False)}"
                     f" drain_at={sc.get('drain_at')}"
                     f" schedule={sc['schedule']}"))
    return eng


# ------------------------------------------------------- always-on seeded sweep

@pytest.mark.parametrize("case", range(4))
def test_differential_seeded(case):
    run_scenario(gen_scenario(np.random.default_rng(PYTEST_SEED * 1009 + case)))


@pytest.mark.slow
@pytest.mark.parametrize("case", range(4, 20))
def test_differential_seeded_big(case):
    run_scenario(gen_scenario(np.random.default_rng(PYTEST_SEED * 1009 + case)))


def test_differential_spec_forced_arm():
    """Pin the speculative arm on for every decode tick (bypassing the
    engine's cost decision) so multi-token accepted commits are exercised
    regardless of what the CostBook would choose on this machine."""
    params, _ = _fixture()
    rng = np.random.default_rng(PYTEST_SEED + 77)
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2, spec_decode=True)
    orig = eng.engine.choose_serve_tick

    def force_spec(*a, **k):
        mode = orig(*a, **k)
        return "spec" if mode == "decode" and k.get("spec_len", 0) > 1 \
            else mode

    eng.engine.choose_serve_tick = force_spec
    prompts = [rng.integers(1, CFG.vocab, (l,)).astype(np.int32)
               for l in (3, 9, 5)]
    reqs = [eng.submit(p, max_new=16) for p in prompts]
    eng.run_until_done()
    assert eng.spec_ticks > 0 and eng.spec_proposed > 0
    for p, r in zip(prompts, reqs):
        np.testing.assert_array_equal(r.output(), oracle(p, 16),
                                      err_msg=f"plen={len(p)}")


@pytest.mark.parametrize("draft", ("self", "target"))
def test_differential_spec_forced_draft_arm(draft):
    """Pin the DRAFT proposer arm on for every decode tick, at both ends of
    the acceptance spectrum: a truncated self-draft of a random-init target
    proposes garbage (all-reject path), the target-as-draft proposes
    perfectly (multi-token commits) — greedy outputs must be bit-identical
    either way, including under prefix-cache seeding and a mid-stream hot
    draft-param swap."""
    params, _ = _fixture()
    rng = np.random.default_rng(PYTEST_SEED + 177)
    kw = {"draft": "self"} if draft == "self" \
        else {"draft_cfg": CFG, "draft_params": params}
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2, spec_decode=True,
                      prefix_cache=True, **kw)
    orig = eng.engine.choose_serve_tick

    def force_draft(*a, **k):
        mode = orig(*a, **k)
        return "spec:draft" if mode.startswith(("decode", "spec")) \
            and k.get("spec_len", 0) > 1 else mode

    eng.engine.choose_serve_tick = force_draft
    shared = rng.integers(1, CFG.vocab, (6,)).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, CFG.vocab, (l,)).astype(
                                   np.int32)]) for l in (3, 7, 2)]
    reqs = [eng.submit(p, max_new=12) for p in prompts]
    # run until at least one draft-arm tick has actually proposed, THEN
    # hot-swap in garbage weights mid-stream: acceptance-only
    ticks = 0
    while eng.spec_arms.get("draft", {}).get("proposed", 0) == 0:
        assert eng.tick() and ticks < 200
        ticks += 1
    eng.engine.controller.send(M.update(
        draft_params=jax.tree.map(lambda x: x * -1, eng.draft_params)))
    eng.run_until_done()
    assert eng.spec_arms["draft"]["ticks"] > 0
    if draft == "target":
        # before the garbage swap the target-as-draft proposals are exact;
        # every proposed token of those ticks must have committed
        assert eng.spec_accepted > 0
    for p, r in zip(prompts, reqs):
        np.testing.assert_array_equal(r.output(), oracle(p, 12),
                                      err_msg=f"draft={draft} plen={len(p)}")


def test_differential_weight_swap_prefix():
    """Force the axis combination the random sweep draws only rarely: a
    mid-stream weight publish with ``prefix_cache`` on and shared-prefix
    prompts.  Before the fix, old-version radix snapshots survived the
    swap and ``longest_match`` ignored the version, so a post-swap request
    seeded from state computed under the old weights (silently wrong under
    a real swap).  Value-identical params keep the oracle valid while the
    version bump drives every invalidation path."""
    params, _ = _fixture()
    rng = np.random.default_rng(PYTEST_SEED + 377)
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2, prefix_cache=True)
    # force-seed admissions so the radix path (not just the result cache)
    # is exercised whatever the CostBook would choose on this machine
    eng.engine.choose_prefix_admission = lambda *a, **k: "seed"
    shared = rng.integers(1, CFG.vocab, (8,)).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, CFG.vocab, (l,)).astype(
                                   np.int32)]) for l in (3, 5, 2, 7)]
    first = [eng.submit(p, max_new=6) for p in prompts[:2]]
    eng.run_until_done()
    assert eng.prefix.snapshots > 0, "no prefix snapshot captured"
    old_v = eng.params_version
    eng.update(params=jax.tree.map(lambda x: x, eng.params),
               params_version=old_v + 1)
    second = [eng.submit(p, max_new=6) for p in prompts[2:]]
    # repeat of a pre-swap prompt: its old-version result-cache entry must
    # NOT answer it under the new version
    repeat = eng.submit(prompts[0], max_new=6)
    eng.run_until_done()
    assert eng.params_version == old_v + 1
    # flush-on-publish dropped every old-version snapshot; whatever was
    # captured since carries the new version
    for n in eng.prefix._snapshot_nodes():
        assert n.version == eng.params_version
    for p, r in zip(prompts, first + second):
        np.testing.assert_array_equal(r.output(), oracle(p, 6),
                                      err_msg=f"plen={len(p)}")
    np.testing.assert_array_equal(repeat.output(), oracle(prompts[0], 6))


# --------------------------------------------------- hypothesis-driven sweep

try:
    from hypothesis import given, seed, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the dev deps
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @seed(PYTEST_SEED)
    @settings(print_blob=True)
    @given(data=st.data())
    def test_differential_hypothesis(data):
        n_req = data.draw(st.integers(1, 5), label="n_req")
        shared = np.asarray(
            data.draw(st.lists(st.integers(1, CFG.vocab - 1),
                               min_size=0, max_size=8), label="shared"),
            np.int32)
        sc = {
            "prompts": [
                (np.concatenate([shared, tail])
                 if shared.size and data.draw(st.booleans(),
                                              label=f"extend_{i}")
                 else tail)
                for i in range(n_req)
                for tail in [np.asarray(
                    data.draw(st.lists(st.integers(1, CFG.vocab - 1),
                                       min_size=1, max_size=12),
                              label=f"prompt_{i}"), np.int32)]],
            "max_news": [data.draw(st.integers(1, 8), label=f"max_new_{i}")
                         for i in range(n_req)],
            "slots": data.draw(st.sampled_from(SLOTS), label="slots"),
            "prefill_chunk": data.draw(st.sampled_from(PREFILL_CHUNKS),
                                       label="prefill_chunk"),
            "decode_chunk": data.draw(st.sampled_from(DECODE_CHUNKS),
                                      label="decode_chunk"),
            "compact": data.draw(st.booleans(), label="compact"),
            "spec": data.draw(st.booleans(), label="spec"),
            "draft": data.draw(st.sampled_from(DRAFTS), label="draft"),
            "prefix_cache": data.draw(st.booleans(), label="prefix_cache"),
            "pools": data.draw(st.integers(1, 2), label="pools"),
            "placements": data.draw(st.booleans(), label="placements"),
            "drain_at": data.draw(st.one_of(st.none(), st.integers(0, 6)),
                                  label="drain_at"),
            "schedule": data.draw(
                st.dictionaries(st.integers(0, 6),
                                st.sampled_from(CTL_KINDS), max_size=2),
                label="schedule"),
            "ctl_seed": data.draw(st.integers(0, 2**31 - 1),
                                  label="ctl_seed"),
            "arrival": data.draw(
                st.one_of(st.none(),
                          st.lists(st.integers(0, 12), min_size=n_req,
                                   max_size=n_req)),
                label="arrival"),
        }
        run_scenario(sc)
