"""Amber control plane: pause/resume/inspect semantics, sub-microbatch
latency, control-replay-log fault tolerance (bit-exact recovery)."""
import os
import shutil
import threading
import time

import numpy as np
import pytest
import jax

from repro.configs import get_arch
from repro.core import messages as M
from repro.core.controller import Controller
from repro.core.breakpoints import (GlobalCountBreakpoint, LocalBreakpoint,
                                    run_global_target_protocol)
from repro.data.synthetic import TokenStream
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.runtime.train import TrainHyper


def mk_loop(tmp, arch="olmoe-1b-7b", ckpt_every=0, controller=None,
            reshaper=None):
    cfg = get_arch(arch + "-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=3)
    return TrainLoop(cfg, stream,
                     TrainHyper(),
                     LoopConfig(microbatches=2, ckpt_every=ckpt_every,
                                ckpt_dir=tmp),
                     controller=controller, reshaper=reshaper)


def test_pause_resume_inspect_while_paused(tmp_path):
    loop = mk_loop(str(tmp_path))
    ctl = loop.controller

    def driver():
        time.sleep(0.3)
        ctl.send(M.pause()).wait(30)
        # inspect WHILE PAUSED (the Amber §2.4.4 capability)
        info = ctl.send(M.inspect()).wait(30)
        assert info["paused"]
        ctl.send(M.update(lr_scale=0.5)).wait(30)
        ctl.send(M.resume()).wait(30)

    th = threading.Thread(target=driver)
    th.start()
    loop.run(6)
    th.join()
    assert loop.lc.lr_scale == 0.5
    kinds = [r.kind for r in ctl.log]
    assert kinds.count("pause") == 1 and kinds.count("resume") == 1
    # pause took effect within one microbatch of wall time
    assert ctl.pause_latency and ctl.pause_latency[0] < 30.0


def test_local_breakpoint_pauses():
    cfg = get_arch("gemma3-1b-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=8, global_batch=2)
    loop = TrainLoop(cfg, stream, TrainHyper(), LoopConfig(microbatches=1))
    ctl = loop.controller
    ctl.send(M.set_breakpoint(LocalBreakpoint("always",
                                              lambda m: m["loss"] > 0)))

    def resumer():
        time.sleep(1.0)
        while not ctl.paused:
            time.sleep(0.1)
        ctl.send(M.stop())

    th = threading.Thread(target=resumer)
    th.start()
    loop.run(10)
    th.join()
    assert "always" in loop.hit_breakpoints


def test_global_count_breakpoint():
    cfg = get_arch("gemma3-1b-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=8, global_batch=2)
    loop = TrainLoop(cfg, stream, TrainHyper(), LoopConfig(microbatches=1))
    bp = GlobalCountBreakpoint("tokens", "tokens", target=3 * 16)
    loop.global_bps.append(bp)

    def stopper():
        while not loop.controller.paused:
            time.sleep(0.05)
        loop.controller.send(M.stop())

    th = threading.Thread(target=stopper)
    th.start()
    loop.run(20)
    th.join()
    assert "tokens" in loop.hit_breakpoints
    # paused within one microbatch of the target
    assert bp._total >= bp.target
    assert bp._total - bp.target <= 16


def test_global_target_protocol_tau_tradeoff():
    # Fig 2.13: higher tau -> more sync time; tiny tau -> best overall
    rates = [10.0, 7.0, 5.0]
    res_small = run_global_target_protocol(1000, rates, tau=0.01)
    res_big = run_global_target_protocol(1000, rates, tau=5.0)
    assert res_small.sync_time < res_big.sync_time
    assert res_small.total_time <= res_big.total_time
    assert res_small.produced >= 1000


def test_sum_predicate_single_worker_endgame_reduces_overshoot():
    rates = [10.0, 9.0, 8.0]
    vals = [15.0, 12.0, 10.0]
    with_endgame = run_global_target_protocol(
        1000, rates, tau=0.1, values_per_tuple=vals,
        single_worker_threshold=50)
    without = run_global_target_protocol(
        1000, rates, tau=0.1, values_per_tuple=vals,
        single_worker_threshold=0)
    assert with_endgame.overshoot <= without.overshoot + 1e-9


def test_durable_log_plan_roundtrip(tmp_path):
    """A 'plan' record (numpy arrays + Migration dataclasses) must survive
    attach_durable_log -> crash -> read_durable_log -> replay.  The old code
    swallowed the json TypeError and silently dropped the record, so a
    recovered worker would route with a stale plan."""
    from repro.core.controller import ReplayingController
    from repro.core.reshape_moe import Migration

    path = str(tmp_path / "control.log")
    ctl = Controller()
    ctl.attach_durable_log(path)
    slots = np.arange(8, dtype=np.int32).reshape(1, 2, 4)
    cum = np.linspace(0.25, 1.0, 8, dtype=np.float32).reshape(1, 2, 4)
    migs = (Migration(0, 1, 3), Migration(0, 2, 6))
    ctl.send(M.set_plan(slots, cum, migs))
    ctl.send(M.update(lr_scale=0.5))
    ctl.poll(step=2, microbatch=1, inspect_fn=None)
    del ctl                                       # "crash"

    records = Controller.read_durable_log(path)
    kinds = [r.kind for r in records]
    assert kinds == ["plan", "update"], kinds      # plan NOT dropped
    pl = records[0].payload
    np.testing.assert_array_equal(np.asarray(pl["slots"]), slots)
    assert np.asarray(pl["slots"]).dtype == np.int32
    np.testing.assert_allclose(np.asarray(pl["cum"]), cum, rtol=1e-6)
    assert [(m.layer, m.src_slot, m.dst_slot) for m in pl["migrations"]] == \
        [(0, 1, 3), (0, 2, 6)]
    assert records[0].step == 2 and records[0].microbatch == 1

    # replay the restored records: the plan must land exactly as sent
    rc = ReplayingController(records)
    r = rc.poll(step=2, microbatch=1)
    assert r["plan"] is not None
    np.testing.assert_array_equal(np.asarray(r["plan"]["slots"]), slots)
    assert r["updates"] == {"lr_scale": 0.5}
    assert [(m.layer, m.src_slot, m.dst_slot)
            for m in r["plan"]["migrations"]] == [(0, 1, 3), (0, 2, 6)]


def test_durable_log_breakpoint_roundtrip(tmp_path):
    """Breakpoint registrations are durably logged: a GlobalCountBreakpoint
    (plain dataclass) restores as the class with its counter state; a
    LocalBreakpoint's lambda predicate cannot be serialized and takes the
    tagged-repr path without killing poll."""
    import warnings
    path = str(tmp_path / "control.log")
    ctl = Controller()
    ctl.attach_durable_log(path)
    ctl.send(M.set_breakpoint(GlobalCountBreakpoint("tok", "tokens",
                                                    target=64.0)))
    ctl.send(M.set_breakpoint(LocalBreakpoint("nan",
                                              lambda m: m["loss"] != 0)))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ctl.poll(step=1, microbatch=0)            # must not raise
    assert any("durable log" in str(x.message) for x in w)
    recs = Controller.read_durable_log(path)
    assert [r.kind for r in recs] == ["breakpoint", "breakpoint"]
    bp = recs[0].payload
    assert isinstance(bp, GlobalCountBreakpoint)
    assert bp.metric == "tokens" and bp.target == 64.0 and bp._total == 0.0
    assert "__unserializable__" in recs[1].payload


def test_durable_log_unserializable_payload_keeps_worker_alive(tmp_path):
    """A payload _json_safe cannot model must neither kill poll() nor
    vanish: it is logged as a tagged repr with a warning."""
    import warnings
    path = str(tmp_path / "control.log")
    ctl = Controller()
    ctl.attach_durable_log(path)
    ctl.send(M.update(tags={"a", "b"}))           # a set is not JSON
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ctl.poll(step=0, microbatch=0)            # must not raise
    assert any("durable log" in str(x.message) for x in w)
    recs = Controller.read_durable_log(path)
    assert recs and recs[0].kind == "update"
    assert "__unserializable__" in recs[0].payload
    assert "tags" in recs[0].payload["__unserializable__"]


@pytest.mark.slow
def test_durable_log_plan_recovery_applies_to_loop(tmp_path):
    """End-to-end: a plan message logged durably before a crash reshapes the
    recovered loop's routing plan at its recorded step."""
    d = str(tmp_path / "ckpt")
    loop = mk_loop(d, ckpt_every=2)
    nl = len(loop.plan_slots)
    loop.run(2)                                   # checkpoint at step 2
    new_slots = np.asarray(loop.plan_slots).copy()
    new_slots[0, 0, :] = (new_slots[0, 0, :] + 1) % new_slots.shape[1]
    new_cum = np.asarray(loop.plan_cum).copy()
    assert not np.array_equal(new_slots, np.asarray(loop.plan_slots))
    loop.controller.send(M.set_plan(new_slots, new_cum, ()))
    loop.run(1)                            # plan applied + logged at (2, 0)
    del loop                                      # crash after step 3

    cfg = get_arch("olmoe-1b-7b-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=3)
    rec = TrainLoop.recover(cfg, stream, TrainHyper(),
                            LoopConfig(microbatches=2, ckpt_every=2,
                                       ckpt_dir=d))
    assert int(rec.state["step"]) == 2
    rec.run(2)                                    # replays the plan at step 3
    np.testing.assert_array_equal(np.asarray(rec.plan_slots), new_slots)
    assert nl == len(rec.plan_slots)


@pytest.mark.slow
def test_fault_tolerance_bit_exact_recovery(tmp_path):
    """Run A: 8 steps with an lr update at step 4 (logged), checkpoint@4.
    Run B: same but 'crash' after step 6, recover from ckpt, replay, finish.
    Final params must be bit-identical."""
    d = str(tmp_path / "ft")

    # --- reference uninterrupted run
    loopA = mk_loop(d + "_a", ckpt_every=4)
    loopA.run(4)
    loopA.controller.send(M.update(lr_scale=0.25))
    loopA.run(4)
    ref = jax.tree.leaves(loopA.state["params"])

    # --- crashing run with identical message schedule
    loopB = mk_loop(d + "_b", ckpt_every=4)
    loopB.run(4)                      # checkpoint at step 4 (message BEFORE
    loopB.controller.send(M.update(lr_scale=0.25))   # any step>4 data)
    loopB.run(2)                      # crash "after step 6"
    del loopB

    cfg = get_arch("olmoe-1b-7b-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=3)
    loopC = TrainLoop.recover(cfg, stream, TrainHyper(),
                              LoopConfig(microbatches=2, ckpt_every=4,
                                         ckpt_dir=d + "_b"))
    assert int(loopC.state["step"]) == 4
    loopC.run(4)                      # replays the update at its logged point
    assert loopC.lc.lr_scale == 0.25
    got = jax.tree.leaves(loopC.state["params"])
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
