"""Unit tests for the knob meta-controller (engine.autotune).

Three layers: deterministic convergence with a SYNTHETIC cost function
(the control loop proven without wall-clock noise), the Engine.choose_knob
decision discipline itself (bootstrap coverage, exploitation, re-explore
rotation), and the load-bearing invariant — an engine tuning its own knobs
mid-stream stays bit-identical to the static greedy oracle.
"""
from functools import lru_cache

import numpy as np
import jax
import pytest

from repro.configs import get_arch
from repro.engine import jobs as J
from repro.engine.autotune import AutoTuner, Knob, default_knobs
from repro.engine.engine import Engine
from repro.engine.serve import ServeEngine
from repro.models import lm
from repro.runtime.serve import BatchedServer

from conftest import PYTEST_SEED

CFG = get_arch("gemma3-1b-smoke")
MAX_LEN = 64


@lru_cache(maxsize=None)
def _fixture():
    params = lm.init(CFG, jax.random.PRNGKey(0))
    return params, BatchedServer(CFG, params, max_len=MAX_LEN)


def _engine(**kw):
    params, _ = _fixture()
    return ServeEngine(CFG, params, max_len=MAX_LEN, **kw)


def _synthetic_tuner(eng, costmap, name="prefill_chunk", window=1,
                     key="prefill_chunk"):
    """Tuner whose window cost is a pure function of the live knob value —
    no wall clock, no tokens, fully deterministic."""
    knob = Knob(name, tuple(sorted(costmap)), key=key)
    tuner = AutoTuner(eng, knobs=[knob], window=window, warmup=0,
                      measure=lambda stats: costmap[getattr(eng, key)])
    eng.autotuner = tuner
    return tuner


# ------------------------------------------------------------ choose_knob

def test_choose_knob_bootstrap_covers_every_arm():
    e = Engine()
    values = (1, 2, 4, 8)
    seen = []
    for v in values:
        got = e.choose_knob("k", values)
        seen.append(got)
        assert isinstance(got, int), "bootstrap must return the TYPED arm"
        e.costs.observe(J.knob_kind("k", got), float(10 - got))
    assert seen == list(values), \
        "bootstrap visits every unmeasured arm in listed order"
    assert e.choose_knob("k", values) == 8, "then exploits the cheapest"


def test_choose_knob_reexplores_losers():
    e = Engine()
    values = (1, 2)
    e.costs.observe(J.knob_kind("k", 1), 5.0)
    e.costs.observe(J.knob_kind("k", 2), 1.0)
    picks = [e.choose_knob("k", values) for _ in range(32)]
    assert picks.count(1) == 2, "the loser re-explores every 16th round"
    assert all(p == 2 for i, p in enumerate(picks) if (i + 1) % 16 != 0)
    deq = [d for d in e.decisions if d["decision"] == "autotune_knob"]
    assert any(d.get("why") == "re-explore" for d in deq)
    assert all("scores" in d for d in deq if "why" not in d)


def test_knob_kind_distinct_arms():
    assert J.knob_kind("spec_len", 4) != J.knob_kind("spec_len", 8)
    assert J.knob_kind("a", 1) != J.knob_kind("b", 1)


# --------------------------------------------------- synthetic convergence

def test_forced_bad_chunk_recovers():
    eng = _engine(slots=2, prefill_chunk=16, decode_chunk=2)
    tuner = _synthetic_tuner(eng, {1: 5.0, 4: 2.0, 16: 1.0})
    eng._apply_updates({"prefill_chunk": 1})
    tuner.current["prefill_chunk"] = 1
    for _ in range(10):
        tuner.on_tick()
    assert tuner.current["prefill_chunk"] == 16, \
        f"did not recover within 10 windows: {tuner.snapshot()}"
    assert eng.prefill_chunk == 16


def test_forced_bad_spec_len_recovers():
    eng = _engine(slots=2, prefill_chunk=8, decode_chunk=2,
                  spec_decode=True)
    costmap = {2: 3.0, 4: 1.0, 8: 2.0}
    knob = Knob("spec_len", (2, 4, 8), key="spec_len")
    tuner = AutoTuner(eng, knobs=[knob], window=1, warmup=0,
                      measure=lambda s: costmap[eng.spec_len])
    eng.autotuner = tuner
    eng._apply_updates({"spec_len": 8})
    tuner.current["spec_len"] = 8
    for _ in range(10):
        tuner.on_tick()
    assert tuner.current["spec_len"] == 4 and eng.spec_len == 4


def test_warmup_windows_discarded():
    """The first window after an arm switch must not enter the EMA — it
    carries the fresh jit specialization in real serving."""
    eng = _engine(slots=2, prefill_chunk=16, decode_chunk=2)
    calls = []

    def measure(stats):
        calls.append(eng.prefill_chunk)
        return 1.0

    knob = Knob("prefill_chunk", (1, 16), key="prefill_chunk")
    tuner = AutoTuner(eng, knobs=[knob], window=1, warmup=1,
                      measure=measure)
    eng.autotuner = tuner
    tuner.on_tick()                      # measures settled 16, moves to 1
    assert eng.prefill_chunk == 1
    n = len(calls)
    tuner.on_tick()                      # warm-up under 1: NOT measured
    assert len(calls) == n
    tuner.on_tick()                      # settled 1: measured
    assert len(calls) == n + 1 and calls[-1] == 1


def test_round_robin_coordinate_descent():
    """Two knobs: windows alternate ownership, each converges on its own
    optimum (the cost function is separable on purpose)."""
    eng = _engine(slots=2, prefill_chunk=16, decode_chunk=2)
    cost = lambda s: ({1: 3.0, 16: 1.0}[eng.prefill_chunk]
                      + {0.25: 0.5, 0.75: 0.0}[eng.compact_frac])
    tuner = AutoTuner(
        eng, knobs=[Knob("prefill_chunk", (1, 16), key="prefill_chunk"),
                    Knob("compact_frac", (0.25, 0.75),
                         key="compact_frac")],
        window=1, warmup=0, measure=cost)
    eng.autotuner = tuner
    eng._apply_updates({"prefill_chunk": 1, "compact_frac": 0.25})
    tuner.current.update({"prefill_chunk": 1, "compact_frac": 0.25})
    for _ in range(14):
        tuner.on_tick()
    assert tuner.current == {"prefill_chunk": 16, "compact_frac": 0.75}


def test_starved_window_dropped_not_scored():
    """A window that committed zero tokens has no signal: the default
    measure returns None and no EMA is written."""
    eng = _engine(slots=2, prefill_chunk=16, decode_chunk=2)
    tuner = AutoTuner(eng, knobs=[Knob("prefill_chunk", (1, 16),
                                       key="prefill_chunk")],
                      window=1, warmup=0)
    assert tuner._measure_wall({"wall_s": 1.0, "tokens": 0.0,
                                "ticks": 4.0}) is None
    assert tuner._measure_wall({"wall_s": 1.0, "tokens": 4.0,
                                "ticks": 4.0}) == 0.25


# ------------------------------------------------------------ knob plumbing

def test_update_handlers_clamp_and_apply():
    eng = _engine(slots=2, prefill_chunk=8, decode_chunk=2)
    eng._apply_updates({"spec_len": 6})
    assert eng.spec_len == 6
    eng._apply_updates({"spec_len": -3})
    assert eng.spec_len == 0
    eng._apply_updates({"compact_frac": 1.7})
    assert eng.compact_frac == 1.0
    eng._apply_updates({"compact_frac": -0.5})
    assert eng.compact_frac == 0.0
    eng._apply_updates({"class_weights": {"default": 9.0}})
    assert eng.classes["default"].weight == 9.0
    with pytest.raises(AssertionError):
        eng._apply_updates({"class_weights": {"nope": 1.0}})


def test_autotune_hot_toggle_via_update():
    eng = _engine(slots=2, prefill_chunk=8, decode_chunk=2)
    assert eng.autotuner is None
    eng._apply_updates({"autotune": {"window": 2, "warmup": 0}})
    assert eng.autotuner is not None and eng.autotuner.window == 2
    assert eng._inspect("all")["autotune"]["enabled"]
    eng._apply_updates({"autotune": False})
    assert eng.autotuner is None
    assert eng._inspect("all")["autotune"] == {"enabled": False}


def test_default_knobs_shape():
    eng = _engine(slots=2, prefill_chunk=8, decode_chunk=2,
                  spec_decode=True)
    knobs = {k.name: k for k in default_knobs(eng)}
    assert "prefill_chunk" in knobs and "compact_frac" in knobs
    assert all(v <= 8 for v in knobs["prefill_chunk"].values), \
        "chunk arms must not exceed the configured chunk (admission)"
    assert "spec_len" in knobs
    # single default class: no weight knob to trade off
    assert not any(n.startswith("weight:") for n in knobs)


def test_class_weight_knob_wrap():
    import dataclasses as dc
    from repro.configs.base import PriorityClass
    cfg = dc.replace(CFG, serve=dc.replace(
        CFG.serve, classes=(PriorityClass("a", 1.0, 4),
                            PriorityClass("b", 2.0, 8))))
    params, _ = _fixture()
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=8, decode_chunk=2)
    knobs = {k.name: k for k in default_knobs(eng)}
    kb = knobs["weight:b"]
    assert kb.current(eng) == 2.0
    eng._apply_updates(kb.updates(4.0))
    assert eng.classes["b"].weight == 4.0 and kb.current(eng) == 4.0
    assert eng.classes["b"].max_defer == 8, \
        "weight retune must not touch the aging bound"


# ------------------------------------------- bit-identicality under tuning

_ORACLE = {}


def oracle(prompt, max_new):
    key = (tuple(int(t) for t in prompt), int(max_new))
    if key not in _ORACLE:
        _, srv = _fixture()
        _ORACLE[key] = srv.generate_static(
            np.asarray(prompt, np.int32)[None], max_new=int(max_new))[0]
    return _ORACLE[key]


def test_tuning_preserves_greedy_bit_identicality():
    """An engine aggressively tuning spec_len + prefill_chunk +
    compact_frac every 2 work ticks (warmup=0: compile windows allowed
    into the EMA — worst case for churn) must produce outputs bit-equal
    to the static oracle.  This is the invariant that licenses autotuning
    in production serving."""
    params, _ = _fixture()
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=3,
                      prefill_chunk=8, decode_chunk=2, spec_decode=True,
                      autotune={"window": 2, "warmup": 0,
                                "knobs": [
                                    Knob("spec_len", (2, 4, 8),
                                         key="spec_len"),
                                    Knob("prefill_chunk", (1, 4, 8),
                                         key="prefill_chunk"),
                                    Knob("compact_frac", (0.25, 0.5, 0.75),
                                         key="compact_frac")]})
    rng = np.random.default_rng(PYTEST_SEED + 4242)
    prompts = [rng.integers(1, CFG.vocab, (int(rng.integers(2, 13)),))
               .astype(np.int32) for _ in range(7)]
    max_news = [int(rng.integers(1, 9)) for _ in prompts]
    reqs = [eng.submit(p, max_new=n) for p, n in zip(prompts, max_news)]
    ticks = 0
    while eng.queue or any(r is not None for r in eng.active):
        assert eng.tick() and ticks < 2000
        ticks += 1
    assert eng.autotuner.windows > 3, "tuner must actually have cycled"
    assert eng.autotuner.moves >= 1
    for p, n, r in zip(prompts, max_news, reqs):
        np.testing.assert_array_equal(
            r.output(), oracle(p, n),
            err_msg=f"plen={len(p)} max_new={n} "
                    f"tuner={eng.autotuner.snapshot()}")
