"""Property tests for the workload generator (engine.loadgen).

The gauntlet's value rests on three generator properties: **determinism**
(same (spec, seed) → identical stream, so every grade is reproducible and
every failure replays), **statistical fidelity** (arrival processes hit
their configured rates, heavy-tail lengths actually have the tail), and
**structure** (priority mixes, shared preambles, sorted arrivals).  Pure
numpy — no engine, no jit — so the whole file runs in milliseconds.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.scheduler import ServeSLO, grade_slo, percentile
from repro.engine import loadgen as lg

from conftest import PYTEST_SEED


# ------------------------------------------------------------ determinism

@pytest.mark.parametrize("name", sorted(lg.SCENARIOS))
def test_generate_deterministic(name):
    spec = lg.SCENARIOS[name]
    a = lg.generate(spec, PYTEST_SEED)
    b = lg.generate(spec, PYTEST_SEED)
    assert a == b, "replay must produce the identical request stream"
    assert len(a) == spec.n
    assert all(a[i].at <= a[i + 1].at for i in range(len(a) - 1)), \
        "stream must be sorted by arrival"
    assert all(r.at >= 0 and r.max_new >= 1 and len(r.prompt) >= 1
               for r in a)


def test_generate_seed_sensitivity():
    spec = lg.SCENARIOS["steady_poisson"]
    assert lg.generate(spec, PYTEST_SEED) != lg.generate(spec,
                                                         PYTEST_SEED + 1)


def test_scenarios_draw_independent_streams():
    """Two scenarios under ONE suite seed must not mirror each other —
    the per-spec name digest decorrelates them."""
    a = lg.generate(lg.SCENARIOS["steady_poisson"], PYTEST_SEED)
    b = lg.generate(dataclasses.replace(lg.SCENARIOS["heavy_tail"],
                                        arrival_params=(("rate", 0.5),)),
                    PYTEST_SEED)
    assert [r.prompt for r in a[:5]] != [r.prompt for r in b[:5]]


# ---------------------------------------------------------------- arrivals

def test_poisson_rate_within_tolerance():
    rng = np.random.default_rng(PYTEST_SEED)
    rate = 0.25
    at = lg.poisson_arrivals(rng, 4000, rate)
    measured = len(at) / max(at[-1], 1)
    assert abs(measured - rate) / rate < 0.15, measured


def test_bursty_structure():
    rng = np.random.default_rng(PYTEST_SEED)
    at = lg.bursty_arrivals(rng, 64, burst=8, gap=50.0)
    ticks, counts = np.unique(at, return_counts=True)
    assert counts.max() == 8, "full bursts arrive together"
    assert (counts == 8).sum() >= 7
    gaps = np.diff(ticks)
    assert gaps.mean() > 5, "burst starts must actually be separated"


def test_diurnal_rate_swings():
    rng = np.random.default_rng(PYTEST_SEED)
    period = 200.0
    at = lg.diurnal_arrivals(rng, 2000, period=period, peak_rate=1.0,
                             trough_rate=0.05)
    # bucket arrivals by phase: the peak half-period must carry several
    # times the trough half-period's traffic
    phase = (at % period) / period
    peak = ((phase >= 0.0) & (phase < 0.5)).sum()
    trough = ((phase >= 0.5) & (phase < 1.0)).sum()
    assert peak > 2 * trough, (peak, trough)


def test_closed_arrivals_all_zero():
    rng = np.random.default_rng(PYTEST_SEED)
    assert (lg.closed_arrivals(rng, 16) == 0).all()


def test_arrival_offsets_dispatch():
    rng = np.random.default_rng(PYTEST_SEED)
    at = lg.arrival_offsets("poisson", 32, rng, rate=0.5)
    assert len(at) == 32 and (np.diff(at) >= 0).all()
    with pytest.raises(KeyError):
        lg.arrival_offsets("nope", 4, rng)


# ----------------------------------------------------------------- lengths

def test_heavy_tail_bounds_and_skew():
    rng = np.random.default_rng(PYTEST_SEED)
    xs = lg.heavy_tail_lengths(rng, 4000, lo=4, hi=400, alpha=1.1)
    assert xs.min() >= 4 and xs.max() <= 400
    # Pareto skew: the mean sits well above the median, and the tail is
    # actually populated
    assert xs.mean() > 1.3 * np.median(xs)
    assert (xs > 100).sum() > 0


def test_uniform_lengths_bounds():
    rng = np.random.default_rng(PYTEST_SEED)
    xs = lg.uniform_lengths(rng, 1000, lo=3, hi=9)
    assert xs.min() == 3 and xs.max() == 9


# --------------------------------------------------------------- structure

def test_priority_mix_proportions():
    spec = dataclasses.replace(
        lg.SCENARIOS["priority_starvation"], n=2000)
    reqs = lg.generate(spec, PYTEST_SEED)
    frac = sum(r.priority == "interactive" for r in reqs) / len(reqs)
    assert abs(frac - 0.75) < 0.05, frac


def test_shared_preamble_population():
    spec = dataclasses.replace(lg.SCENARIOS["shared_preamble"], n=64)
    reqs = lg.generate(spec, PYTEST_SEED)
    heads = {}
    for r in reqs:
        k = r.prompt[:4]
        heads[k] = heads.get(k, 0) + 1
    # n_preambles=2: the prompt population collapses onto two 4-token
    # heads (modulo very short prompts), where disjoint prompts would
    # scatter across ~64 distinct heads
    assert len(heads) <= 6, heads
    assert max(heads.values()) >= len(reqs) // 4


def test_disjoint_population_scatters():
    spec = dataclasses.replace(lg.SCENARIOS["steady_poisson"], n=64,
                               plen_params=(("lo", 8), ("hi", 12)))
    reqs = lg.generate(spec, PYTEST_SEED)
    assert len({r.prompt[:4] for r in reqs}) > 32


def test_events_schedule_shape():
    spec = lg.SCENARIOS["hot_swap_storm"]
    ev = spec.event_list()
    assert ev and all(isinstance(t, int) and isinstance(d, dict)
                      for t, d in ev)
    assert all("params_version" in d for _, d in ev)


# ------------------------------------------------- SLO grading primitives

def test_percentile_nearest_rank():
    xs = [10, 20, 30, 40]
    assert percentile(xs, 50) == 20       # ceil(0.5*4)=2nd
    assert percentile(xs, 100) == 40
    assert percentile(xs, 1) == 10
    assert percentile([], 50) == float("inf")
    assert percentile([7], 99) == 7


def test_grade_slo_pass_fail_and_missing():
    slo = [ServeSLO(p99_ttft=10, min_goodput=1.0),
           ServeSLO(scope="vip", p50_ttft=5)]
    ok, d = grade_slo({"p99_ttft": 8.0, "goodput": 2.0,
                       "vip/p50_ttft": 4.0, "dropped": 0.0,
                       "vip/dropped": 0.0}, slo)
    assert ok and all(v.startswith("pass") for v in d.values())
    ok, d = grade_slo({"p99_ttft": 12.0, "goodput": 2.0, "dropped": 0.0,
                       "vip/dropped": 0.0}, slo)
    assert not ok
    assert d["p99_ttft"].startswith("FAIL")
    assert d["vip/p50_ttft"].startswith("FAIL:missing"), \
        "a bound whose metric is missing must fail, not vacuously pass"


# ------------------------------------------------------- hypothesis layer

try:
    from hypothesis import given, seed, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the dev deps
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @seed(PYTEST_SEED)
    @settings(print_blob=True)
    @given(seed_=st.integers(0, 2**31 - 1),
           name=st.sampled_from(sorted(lg.SCENARIOS)),
           n=st.integers(1, 40))
    def test_generate_properties_hypothesis(seed_, name, n):
        spec = dataclasses.replace(lg.SCENARIOS[name], n=n)
        a = lg.generate(spec, seed_)
        assert a == lg.generate(spec, seed_)
        assert len(a) == n
        assert all(a[i].at <= a[i + 1].at for i in range(len(a) - 1))
        lo = dict(spec.plen_params)["lo"]
        hi = dict(spec.plen_params)["hi"]
        assert all(lo <= len(r.prompt) <= hi for r in a)
        mix = dict(spec.mix)
        assert all(r.priority in mix for r in a)

    @seed(PYTEST_SEED)
    @settings(print_blob=True)
    @given(seed_=st.integers(0, 2**31 - 1),
           rate=st.floats(0.05, 2.0),
           kind=st.sampled_from(["poisson", "closed"]))
    def test_arrival_offsets_properties(seed_, rate, kind):
        rng = np.random.default_rng(seed_)
        kw = {"rate": rate} if kind == "poisson" else {}
        at = lg.arrival_offsets(kind, 64, rng, **kw)
        assert len(at) == 64
        assert (at >= 0).all() and (np.diff(at) >= 0).all()
