"""Draft-model proposer family: config/param slicing, state threading, and
the engine's per-arm arbitration (repro.engine.draft + the draft arm of
engine.serve).  The bit-identicality sweeps live in
tests/test_serve_differential.py; here are the targeted unit properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.engine.draft import (distill_draft, greedy_streams,
                                slice_draft_params, small_draft_cfg,
                                truncated_draft_cfg)
from repro.engine.serve import ServeEngine
from repro.models import lm

from conftest import PYTEST_SEED

CFG = get_arch("gemma3-1b-smoke")


@pytest.fixture(scope="module")
def params():
    return lm.init(CFG, jax.random.PRNGKey(0))


# ------------------------------------------------------------ config slicing

def test_truncated_draft_cfg_is_a_pattern_prefix():
    d = truncated_draft_cfg(CFG, 2)
    assert d.pattern == CFG.pattern[:2]
    assert d.num_layers == 2
    # every width is the target's: the self-draft params are SLICES
    assert (d.d_model, d.n_heads, d.vocab) == \
        (CFG.d_model, CFG.n_heads, CFG.vocab)
    with pytest.raises(AssertionError):
        truncated_draft_cfg(CFG, CFG.num_layers)     # must be a strict prefix
    with pytest.raises(AssertionError):
        truncated_draft_cfg(CFG, 0)


def test_slice_draft_params_shapes_and_aliasing(params):
    dcfg = truncated_draft_cfg(CFG, 2)
    dp = slice_draft_params(params, CFG, dcfg)
    # stacked leading dims shrink to the prefix's per-type counts
    counts = {}
    for t in dcfg.pattern:
        counts[t] = counts.get(t, 0) + 1
    for t, n in counts.items():
        for leaf in jax.tree.leaves(dp[t]):
            assert leaf.shape[0] == n
    # shared head groups ride along whole
    for k in ("embed", "final_ln", "lm_head"):
        if k in params:
            assert jax.tree.structure(dp[k]) == jax.tree.structure(params[k])
    # slices are fresh buffers: donating/updating the target cannot alias
    t0 = dcfg.pattern[0]
    leaf = jax.tree.leaves(dp[t0])[0]
    src = jax.tree.leaves(params[t0])[0]
    assert leaf.unsafe_buffer_pointer() != src.unsafe_buffer_pointer()
    # the sliced tree actually runs as a model
    st = lm.init_cache(dcfg, 1, 8)
    logits, _ = lm.decode_step(dp, st, jnp.ones((1, 1), jnp.int32), dcfg)
    assert logits.shape == (1, CFG.vocab)


def test_small_draft_cfg_dims():
    d = small_draft_cfg(CFG, layers=1, d_model=32, n_heads=2)
    assert d.num_layers == 1 and d.pattern == CFG.pattern[:1]
    assert d.d_model == 32 and d.vocab == CFG.vocab
    p = lm.init(d, jax.random.PRNGKey(1))
    st = lm.init_cache(d, 1, 8)
    logits, _ = lm.decode_step(p, st, jnp.ones((1, 1), jnp.int32), d)
    assert logits.shape == (1, CFG.vocab)


# -------------------------------------------------------- plain-arm threading

def test_draft_threading_never_changes_plain_outputs(params):
    """With a draft loaded but spec off, every tick still advances the
    draft rows (the shadow feed) — outputs must equal the draft-free
    engine's bit for bit, greedy and sampled alike."""
    rng = np.random.default_rng(PYTEST_SEED + 5)
    prompts = rng.integers(1, CFG.vocab, (3, 7)).astype(np.int32)
    ref = ServeEngine(CFG, params, max_len=64).generate(
        prompts, max_new=8, seed=3)
    got = ServeEngine(CFG, params, max_len=64, draft="self").generate(
        prompts, max_new=8, seed=3)
    np.testing.assert_array_equal(got, ref)
    # sampled traffic too: the draft feed must not touch the key stream
    ref_s = ServeEngine(CFG, params, max_len=64).generate(
        prompts, max_new=8, temperature=0.9, seed=4)
    got_s = ServeEngine(CFG, params, max_len=64, draft="self").generate(
        prompts, max_new=8, temperature=0.9, seed=4)
    np.testing.assert_array_equal(got_s, ref_s)


def test_draft_rows_live_in_pool_and_reset_on_join(params):
    eng = ServeEngine(CFG, params, max_len=64, slots=2, draft="self",
                      spec_decode=True)
    sp = eng.pools[0]
    assert "draft" in sp.pool
    for leaf in jax.tree.leaves(sp.pool["draft"]):
        assert leaf.shape[0] == sp.slots
    # churn requests through the two slots; draft state never leaks (the
    # differential harness pins outputs; here just exercise re-join)
    rng = np.random.default_rng(PYTEST_SEED)
    for _ in range(2):
        prompts = rng.integers(1, CFG.vocab, (4, 5)).astype(np.int32)
        eng.generate(prompts, max_new=4)
    assert not any(r is not None for r in eng.active)


def test_snapshot_rows_carry_draft_state(params):
    """Prefix-cache snapshots capture the whole pool row — draft leaves
    included — so a seeded slot resumes with a warm draft."""
    eng = ServeEngine(CFG, params, max_len=64, slots=2, prefill_chunk=4,
                      draft="self", prefix_cache=True)
    rng = np.random.default_rng(PYTEST_SEED + 9)
    prompt = rng.integers(1, CFG.vocab, (12,)).astype(np.int32)
    eng.generate(prompt[None], max_new=4)
    snaps = [n for n in [eng.prefix.lookup(prompt[:k])
                         for k in range(4, 13)]
             if n is not None and n.snapshot is not None]
    assert snaps, "no prefix snapshot was captured"
    assert "draft" in snaps[0].snapshot
    # a second, prefix-sharing request seeds from it and stays identical
    ext = np.concatenate([prompt, rng.integers(1, CFG.vocab, (3,))
                          .astype(np.int32)])
    ref = ServeEngine(CFG, params, max_len=64).generate(ext[None],
                                                        max_new=6)
    got = eng.generate(ext[None], max_new=6)
    np.testing.assert_array_equal(got, ref)
    assert eng.prefix.seeded >= 1


# ------------------------------------------------------------------- distill

@pytest.mark.slow
def test_distilled_draft_reaches_high_acceptance(params):
    """The distillation recipe: a tiny independent draft trained on the
    target's own greedy streams must reach high argmax agreement — enough
    that the draft arm's accepted/proposed ratio beats any n-gram table on
    non-repetitive traffic."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, CFG.vocab, (8,)).astype(np.int32)
               for _ in range(6)]
    dcfg = small_draft_cfg(CFG)
    dparams = distill_draft(CFG, params, dcfg, prompts, max_new=48,
                            steps=300, seed=PYTEST_SEED)
    eng = ServeEngine(CFG, params, max_len=96, slots=2, prefill_chunk=4,
                      decode_chunk=4, spec_decode=True, draft_cfg=dcfg,
                      draft_params=dparams)
    orig = eng.engine.choose_serve_tick
    eng.engine.choose_serve_tick = lambda *a, **k: (
        "spec:draft" if orig(*a, **k) != "prefill"
        and k.get("spec_len", 0) > 1 else orig(*a, **k))
    outs = eng.generate(np.stack(prompts[:4]), max_new=32)
    ref = ServeEngine(CFG, params, max_len=96).generate(
        np.stack(prompts[:4]), max_new=32)
    np.testing.assert_array_equal(outs, ref)
    st = eng.spec_arms["draft"]
    assert st["proposed"] > 0
    assert st["accepted"] / st["proposed"] >= 0.5, st


@pytest.mark.slow
def test_greedy_streams_match_serve_outputs(params):
    """The distillation teacher (batched scan rollout) and the serve path
    agree on greedy continuations — the teacher trains the draft on
    exactly the traffic it will propose for."""
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, CFG.vocab, (6,)).astype(np.int32)
               for _ in range(3)]
    streams = greedy_streams(CFG, params, prompts, max_new=8, max_len=32)
    ref = ServeEngine(CFG, params, max_len=32).generate(
        np.stack(prompts), max_new=8)
    for s, p, r in zip(streams, prompts, ref):
        np.testing.assert_array_equal(s[len(p):], r)
