"""Cross-request prefix cache + result cache: unit + engine-level tests.

Three layers:

* pure host-side data structures — radix insert / longest-match / split,
  LRU eviction under refcount and pinning, request-fingerprint
  canonicalization, the workload analyzer's hot-prefix mining;
* the engine decision — ``Engine.choose_prefix_admission`` flips between
  seed and prefill as the cooked CostBook EMAs move, bootstraps toward the
  unmeasured seed arm, and re-explores a losing seed arm;
* ServeEngine end-to-end — a shared-prefix workload seeds admissions and
  stays bit-identical to the static oracle, exact repeats answer from the
  result cache without taking a slot, sampled requests never seed or
  store, and the counters surface through ``_inspect("prefix_cache")``.
"""
from functools import lru_cache

import numpy as np
import jax
import pytest

from repro.configs import get_arch
from repro.engine.engine import Engine
from repro.engine.prefix_cache import (PrefixAnalyzer, PrefixCache,
                                       request_fingerprint)
from repro.engine.serve import ServeEngine
from repro.models import lm
from repro.runtime.serve import BatchedServer

from conftest import PYTEST_SEED

CFG = get_arch("gemma3-1b-smoke")
MAX_LEN = 64


@lru_cache(maxsize=None)
def _fixture():
    params = lm.init(CFG, jax.random.PRNGKey(0))
    return params, BatchedServer(CFG, params, max_len=MAX_LEN)


# ------------------------------------------------------------- fingerprint

def test_fingerprint_canonicalizes_dtype_and_container():
    toks = [3, 1, 4, 1, 5]
    fps = {request_fingerprint(c, 8, 0.0, 0) for c in (
        toks, tuple(toks), np.asarray(toks, np.int32),
        np.asarray(toks, np.int64))}
    assert len(fps) == 1


def test_fingerprint_greedy_temperatures_collapse():
    assert request_fingerprint([1, 2], 4, 0.0, 0) == \
        request_fingerprint([1, 2], 4, -1.0, 0)


def test_fingerprint_sampled_is_uncacheable():
    assert request_fingerprint([1, 2], 4, 0.7, 0) is None


def test_fingerprint_params_version_keys():
    assert request_fingerprint([1, 2], 4, 0.0, 0) != \
        request_fingerprint([1, 2], 4, 0.0, 1)


def test_fingerprint_max_new_not_in_key():
    assert request_fingerprint([1, 2], 4, 0.0, 0) == \
        request_fingerprint([1, 2], 99, 0.0, 0)


# ------------------------------------------------------------ result cache

def test_result_cache_truncation_hit_and_short_miss():
    pc = PrefixCache(min_len=2)
    pc.result_store([1, 2, 3], 8, 0.0, 0, [10, 11, 12, 13, 14, 15, 16, 17])
    # shorter request answered by truncation (greedy is prefix-stable)
    assert pc.result_lookup([1, 2, 3], 5, 0.0, 0) == [10, 11, 12, 13, 14]
    # a LONGER request is not answerable by the stored continuation
    assert pc.result_lookup([1, 2, 3], 9, 0.0, 0) is None
    # sampled requests miss even on an identical prompt
    assert pc.result_lookup([1, 2, 3], 5, 0.9, 0) is None
    # a different params version must miss (stale weights)
    assert pc.result_lookup([1, 2, 3], 5, 0.0, 1) is None


def test_result_cache_longer_replaces_shorter():
    pc = PrefixCache(min_len=2)
    pc.result_store([7], 2, 0.0, 0, [1, 2])
    assert pc.result_lookup([7], 4, 0.0, 0) is None
    pc.result_store([7], 4, 0.0, 0, [1, 2, 3, 4])
    assert pc.result_lookup([7], 4, 0.0, 0) == [1, 2, 3, 4]
    # and the shorter store does NOT clobber the longer entry
    pc.result_store([7], 2, 0.0, 0, [1, 2])
    assert pc.result_lookup([7], 4, 0.0, 0) == [1, 2, 3, 4]


def test_result_cache_sampled_never_stores():
    pc = PrefixCache(min_len=2)
    assert not pc.result_store([1], 4, 0.9, 0, [5, 6, 7, 8])
    assert pc.result_lookup([1], 4, 0.0, 0) is None


def test_result_cache_lru_bound():
    pc = PrefixCache(min_len=2, result_entries=2)
    for i in range(4):
        pc.result_store([i], 1, 0.0, 0, [i])
    assert pc.result_lookup([0], 1, 0.0, 0) is None   # aged out
    assert pc.result_lookup([3], 1, 0.0, 0) == [3]


# -------------------------------------------------------------- radix tree

def test_radix_insert_longest_match_and_split():
    pc = PrefixCache(min_len=2)
    pc.insert([1, 2, 3, 4], snapshot="s4")
    pc.insert([1, 2, 3, 4, 5, 6], snapshot="s6")
    # divergence inside the compressed [5, 6] edge forces a split
    pc.insert([1, 2, 3, 4, 5, 9], snapshot="alt")
    assert pc.longest_match([1, 2, 3, 4, 5, 6, 7, 8]).snapshot == "s6"
    assert pc.longest_match([1, 2, 3, 4, 5, 9, 9]).snapshot == "alt"
    # limit: a snapshot consuming the whole query is not a usable seed
    assert pc.longest_match([1, 2, 3, 4], limit=3) is None
    assert pc.longest_match([1, 2, 3, 4, 9], limit=4).snapshot == "s4"
    # disjoint prompt: miss
    assert pc.longest_match([9, 9, 9, 9]) is None
    assert pc.misses == 2 and pc.hits == 3


def test_radix_min_len_rejects_short_paths():
    pc = PrefixCache(min_len=4)
    assert pc.insert([1, 2, 3], snapshot="x") is None
    assert pc.snapshots == 0


def test_radix_lookup_exact_no_counters():
    pc = PrefixCache(min_len=2)
    pc.insert([1, 2, 3], snapshot="s")
    assert pc.lookup([1, 2, 3]).snapshot == "s"
    assert pc.lookup([1, 2]) is None        # interior of a compressed edge
    assert pc.hits == 0 and pc.misses == 0


def test_lru_eviction_order():
    pc = PrefixCache(capacity=2, min_len=2)
    pc.insert([1, 1, 1], snapshot="a")
    pc.insert([2, 2, 2], snapshot="b")
    pc.longest_match([1, 1, 1, 9])          # touch "a" -> "b" is now LRU
    pc.insert([3, 3, 3], snapshot="c")
    assert pc.evictions == 1
    assert pc.lookup([2, 2, 2]) is None     # evicted AND pruned
    assert pc.longest_match([1, 1, 1, 9]).snapshot == "a"
    assert pc.snapshots == 2


def test_refcount_blocks_eviction():
    pc = PrefixCache(capacity=1, min_len=2)
    n = pc.insert([1, 1, 1], snapshot="a")
    pc.acquire(n)
    pc.insert([2, 2, 2], snapshot="b")      # over capacity, "a" is pinned
    # "b" itself is evictable, so capacity recovers by dropping it; "a"
    # (referenced) must survive
    assert pc.lookup([1, 1, 1]).snapshot == "a"
    pc.release(n)
    pc.insert([3, 3, 3], snapshot="c")
    assert pc.lookup([1, 1, 1]) is None     # refs drained -> evictable


def test_all_protected_runs_over_capacity():
    pc = PrefixCache(capacity=1, min_len=2)
    a = pc.insert([1, 1, 1], snapshot="a")
    pc.acquire(a)                            # in-flight seed
    pc.pin([2, 2, 2])
    pc.insert([2, 2, 2], snapshot="b")       # born pinned
    # nothing evictable: the bound is deliberately exceeded rather than
    # corrupting an in-flight seed or dropping a pinned prefix
    assert pc.snapshots == 2
    assert pc.lookup([1, 1, 1]).snapshot == "a"
    assert pc.lookup([2, 2, 2]).snapshot == "b"


def test_pin_blocks_eviction_and_pre_pins_future_snapshot():
    pc = PrefixCache(capacity=1, min_len=2)
    pc.pin([1, 1, 1])                        # path not in the tree yet
    pc.insert([1, 1, 1], snapshot="a")       # born pinned
    pc.insert([2, 2, 2], snapshot="b")
    assert pc.lookup([1, 1, 1]).snapshot == "a"
    assert pc.pinned == 1


# ---------------------------------------------------------------- analyzer

def test_analyzer_mines_hot_prefixes_on_grid():
    an = PrefixAnalyzer(min_len=2, pin_count=3, history=100)
    shared = (5, 6, 7, 8, 9)
    for i in range(3):
        an.record(shared + (100 + i,))       # shared 5-token preamble
    an.record((1, 2, 3))                     # noise, seen once
    hot = an.hot_prefixes()
    assert shared[:4] in hot and shared[:2] in hot   # grid: 2, 4
    assert (1, 2) not in hot
    # longest first: pinning the deepest shared run dominates
    assert hot[0] == shared[:4]


def test_analyzer_sliding_window_expires():
    an = PrefixAnalyzer(min_len=2, pin_count=3, history=4)
    for _ in range(3):
        an.record((1, 2, 3))
    assert (1, 2) in an.hot_prefixes()
    for _ in range(4):
        an.record((7, 8, 9))                 # push the window past the 1s
    assert (1, 2) not in an.hot_prefixes()


# ---------------------------------------------------------- engine decision

def test_choose_prefix_admission_bootstraps_seed():
    eng = Engine()
    assert eng.choose_prefix_admission(8, 2) == "seed"
    assert eng.decisions[-1]["why"] == "bootstrap"


def test_choose_prefix_admission_tracks_cooked_emas():
    eng = Engine()
    # cheap copy, expensive per-token prefill: seeding 30 cached tokens
    # beats recomputing them
    eng.costs.observe("serve_seed", 0.001)
    eng.costs.observe("serve_prefill_per_tok", 0.010)
    assert eng.choose_prefix_admission(30, 4) == "seed", eng.decisions[-1]
    # expensive copy, cheap prefill: recomputing 5 tokens beats the copy
    eng2 = Engine()
    eng2.costs.observe("serve_seed", 1.0)
    eng2.costs.observe("serve_prefill_per_tok", 0.0001)
    assert eng2.choose_prefix_admission(5, 4) == "prefill"


def test_choose_prefix_admission_reexplores_losing_seed_arm():
    eng = Engine()
    eng.costs.observe("serve_seed", 1.0)
    eng.costs.observe("serve_prefill_per_tok", 0.0001)
    picks = [eng.choose_prefix_admission(5, 4) for _ in range(16)]
    assert picks.count("seed") == 1          # the forced 16th-round explore
    assert picks[:15] == ["prefill"] * 15


# --------------------------------------------------------- engine end-to-end

def _oracle(prompt, max_new):
    _, srv = _fixture()
    return srv.generate_static(np.asarray(prompt, np.int32)[None],
                               max_new=int(max_new))[0]


def test_serve_prefix_cache_seeds_and_stays_bit_identical():
    params, _ = _fixture()
    rng = np.random.default_rng(PYTEST_SEED + 31)
    shared = rng.integers(1, CFG.vocab, 12).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, CFG.vocab, 3).astype(np.int32)])
               for _ in range(5)]
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2, prefix_cache=True)
    reqs = [eng.submit(p, max_new=5) for p in prompts]
    eng.run_until_done()
    for i, (p, r) in enumerate(zip(prompts, reqs)):
        np.testing.assert_array_equal(r.output(), _oracle(p, 5),
                                      err_msg=f"req {i}")
    st = eng._inspect("prefix_cache")["prefix_cache"]
    assert st["enabled"] and st["seeded"] >= 1
    assert st["tokens_avoided"] >= st["seeded"] * CFG.serve.prefix_min_len
    assert st["snapshots"] >= 1


def test_serve_exact_repeat_hits_result_cache_without_slot():
    params, _ = _fixture()
    rng = np.random.default_rng(PYTEST_SEED + 32)
    prompt = rng.integers(1, CFG.vocab, 7).astype(np.int32)
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2, prefix_cache=True)
    r1 = eng.submit(prompt, max_new=6)
    eng.run_until_done()
    ticks_before = eng.tick_no
    r2 = eng.submit(prompt, max_new=6)       # exact repeat
    r3 = eng.submit(prompt, max_new=4)       # shorter: truncation hit
    eng.run_until_done()
    np.testing.assert_array_equal(r2.output(), r1.output())
    np.testing.assert_array_equal(r3.output(), r1.output()[:4])
    st = eng._inspect("prefix_cache")["prefix_cache"]
    assert st["result_hits"] == 2
    # a result hit never occupies a slot, so no tick ran any model work
    # (idle ticks do not advance tick_no)
    assert eng.tick_no == ticks_before


def test_serve_sampled_requests_never_seed_or_store():
    params, _ = _fixture()
    rng = np.random.default_rng(PYTEST_SEED + 33)
    prompt = rng.integers(1, CFG.vocab, 8).astype(np.int32)
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2, prefix_cache=True)
    eng.submit(prompt, max_new=4, temperature=0.8)
    eng.run_until_done()
    eng.submit(prompt, max_new=4, temperature=0.8)
    eng.run_until_done()
    st = eng._inspect("prefix_cache")["prefix_cache"]
    assert st["seeded"] == 0 and st["result_hits"] == 0
    assert st["result_entries"] == 0


def test_serve_prefix_cache_hot_toggle():
    params, _ = _fixture()
    rng = np.random.default_rng(PYTEST_SEED + 34)
    prompt = rng.integers(1, CFG.vocab, 8).astype(np.int32)
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2)
    assert eng._inspect("x")["prefix_cache"] == {"enabled": False}
    eng._apply_updates({"prefix_cache": True})
    r = eng.submit(prompt, max_new=4)
    eng.run_until_done()
    np.testing.assert_array_equal(r.output(), _oracle(prompt, 4))
    assert eng._inspect("x")["prefix_cache"]["enabled"]
    eng._apply_updates({"prefix_cache": False})
    assert eng._inspect("x")["prefix_cache"] == {"enabled": False}


def test_serve_params_version_update_keys_result_cache():
    params, _ = _fixture()
    rng = np.random.default_rng(PYTEST_SEED + 35)
    prompt = rng.integers(1, CFG.vocab, 7).astype(np.int32)
    eng = ServeEngine(CFG, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=4, decode_chunk=2, prefix_cache=True)
    eng.submit(prompt, max_new=4)
    eng.run_until_done()
    eng._apply_updates({"params_version": 1})   # simulated weight swap
    eng.submit(prompt, max_new=4)
    eng.run_until_done()
    st = eng._inspect("x")["prefix_cache"]
    assert st["result_hits"] == 0               # old answers must not serve
