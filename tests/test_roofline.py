"""Analytic-model validation: param_count vs real initialized sizes (exact),
HLO collective parser on known text, roofline term sanity."""
import numpy as np
import jax
import pytest

from repro.analysis import flops as F
from repro.analysis.hlo import collective_bytes, total_collective_bytes
from repro.analysis.roofline import analyze
from repro.configs import ARCH_IDS, SHAPES, get_arch
from repro.models import lm


@pytest.mark.parametrize("arch", ["gemma3-1b", "olmoe-1b-7b", "rwkv6-1.6b",
                                  "zamba2-7b", "whisper-base"])
def test_param_count_matches_init(arch):
    cfg = get_arch(arch + "-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    real = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    assert F.param_count(cfg) == real


def test_param_count_flagship_sizes():
    # sanity: the assigned archs land near their nameplate sizes
    assert 95e9 < F.param_count(get_arch("command-r-plus-104b")) < 115e9
    assert 30e9 < F.param_count(get_arch("yi-34b")) < 38e9
    n_olmoe = F.param_count(get_arch("olmoe-1b-7b"))
    a_olmoe = F.param_count(get_arch("olmoe-1b-7b"), active_only=True)
    assert a_olmoe < n_olmoe / 3          # top-8 of 64 experts
    assert 1.4e9 < F.param_count(get_arch("rwkv6-1.6b")) < 2.0e9


HLO = """\
ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%p0), to_apply=%add
  %w = (s32[], f32[4,4]{1,0}) while(%t), condition=%cond, body=%region_1.2
  ROOT %out = f32[8,16]{1,0} add(%ar, %ar)
}

%region_1.2 (arg: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %ag = f32[4,4]{1,0} all-gather(%x), dimensions={0}
}
"""


def test_hlo_parser_counts_and_multiplies():
    c = collective_bytes(HLO, while_multiplier=10.0)
    assert c["all-reduce"] == 8 * 16 * 4              # top level, x1
    assert c["all-gather"] == 4 * 4 * 4 * 10          # in while body, x10
    assert total_collective_bytes(HLO, 10.0) == 512 + 640


@pytest.mark.parametrize("shape", list(SHAPES))
def test_roofline_terms_positive_all_cells(shape):
    mesh = {"data": 16, "model": 16}
    for arch in ARCH_IDS:
        cfg = get_arch(arch)
        if shape == "long_500k" and not cfg.subquadratic:
            continue
        rl = analyze(cfg, SHAPES[shape], mesh, remat="full")
        assert rl.compute_s > 0 and rl.memory_s > 0
        assert rl.collective_s >= 0
        assert 0 < rl.usefulness <= 1.3, (arch, shape, rl.usefulness)
        assert 0 < rl.roofline_fraction <= 1.0, (arch, shape)


def test_decode_memory_levers():
    """fp8 KV + weight-stationary decode must shrink the memory term."""
    cfg = get_arch("yi-34b")
    shape = SHAPES["decode_32k"]
    mesh = {"data": 16, "model": 16}
    base = analyze(cfg, shape, mesh)
    opt = analyze(cfg, shape, mesh, kv_bytes=1, seq_shard_decode=True)
    assert opt.memory_s < 0.5 * base.memory_s
