"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
dryrun_results.json (+ perf variant jsons).  Narrative sections live in
EXPERIMENTS.md directly; this script rewrites only the generated block
between the AUTOGEN markers."""
import json
import sys

BEGIN = "<!-- AUTOGEN:TABLES BEGIN -->"
END = "<!-- AUTOGEN:TABLES END -->"


def table(results):
    out = []
    out.append("### §Dry-run — every (arch × shape) × mesh cell\n")
    n_pass = sum(1 for r in results if r.get("ok"))
    n_skip = sum(1 for r in results if r.get("ok") is None)
    n_fail = sum(1 for r in results if r.get("ok") is False)
    out.append(f"**{n_pass} compiled, {n_fail} failed, {n_skip} skipped** "
               "(skips = long_500k on pure full-attention archs, per "
               "assignment; reasons recorded per cell).  "
               "`.lower().compile()` succeeded for every applicable cell on "
               "both the single-pod 16×16 (256-chip) and multi-pod 2×16×16 "
               "(512-chip) meshes.  Baselines below use remat=full, "
               "layout=tp (Megatron-style TP over `model` + FSDP over "
               "`data`/`pod`).\n")
    out.append("| arch | shape | mesh | compile s | mem GB/dev | argbytes "
               "GB | HLO coll GB/dev* | cost_analysis flops |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in results:
        if r.get("ok") is None:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                       f"| — | — | SKIP: {r['skip_reason'][:60]}… |")
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                       f"| FAIL | | | | {r.get('error', '')[:60]} |")
            continue
        coll = sum(v for k, v in r["collectives"].items()
                   if not k.startswith("_"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']} | {r['memory']['total_per_device_gb']} "
            f"| {r['memory']['argument_bytes'] / 2**30:.2f} "
            f"| {coll / 2**30:.2f} | {r['cost_analysis']['flops']:.2e} |")
    out.append("\n\\* HLO-text parse of collective result shapes with a flat "
               "scan-trip multiplier (num_layers); nested microbatch loops "
               "make this a lower bound — see §Roofline notes.\n")

    out.append("### §Roofline — three terms per cell (single-pod baseline)\n")
    out.append("Terms from the analytic model (ring-collective convention; "
               "DESIGN.md §3 explains why `cost_analysis` cannot be used "
               "directly for scan programs).  Hardware: 197 TFLOP/s bf16, "
               "819 GB/s HBM, 50 GB/s/link ICI per chip.\n")
    out.append("| arch | shape | compute s | memory s | collective s "
               "(analytic; HLO raw) | bottleneck | MODEL_FLOPS | MODEL/HLO | "
               "roofline frac | what would move the dominant term |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    moves = {
        "compute": "less remat recompute (dots policy where it fits), lower MoE capacity waste via Reshape",
        "memory": "fp8 KV cache + weight-stationary 2-D decode sharding (see §Perf C)",
        "collective": "grad compression on the DP sync; overlap AG with compute",
    }
    from repro.launch.mesh import ICI_BW, PEAK_FLOPS_BF16
    for r in results:
        if not r.get("ok") or r["mesh"] != "16x16":
            continue
        rr = r["roofline"]
        # primary term: the analytic model (stated ring-collective
        # convention); raw HLO-text bytes (loop bodies counted once — a
        # lower bound) are shown alongside as the compiled observable.
        hlo_gb = rr.get("hlo_collective_bytes", 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {rr['compute_s']:.3e} "
            f"| {rr['memory_s']:.3e} | {rr['collective_s']:.3e} "
            f"(HLO raw {hlo_gb:.1f} GB) "
            f"| **{rr['dominant']}** | {rr['model_flops']:.2e} "
            f"| {rr['usefulness']:.2f} | {rr['roofline_fraction']:.1%} "
            f"| {moves[rr['dominant']]} |")
    out.append("")
    return "\n".join(out)


def main():
    results = json.load(open("dryrun_results.json"))
    block = table(results)
    src = open("EXPERIMENTS.md").read()
    pre, rest = src.split(BEGIN)
    _, post = rest.split(END)
    open("EXPERIMENTS.md", "w").write(
        pre + BEGIN + "\n" + block + "\n" + END + post)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
