"""Export the engine's decision telemetry ring buffer as JSONL.

Every ``Engine.choose_*`` call records one decision dict — the arm scores,
CostBook inputs, and the winner — into a bounded ring buffer surfaced
through ``ServeEngine._inspect("decisions")``.  This script drains that
buffer to one-JSON-object-per-line, the grep/pandas-friendly audit-trail
format: *why* did the scheduler pick that pool / that tick composition /
that migration destination, priced by *which* measured EMAs.

Library use (e.g. from a notebook or a bench harness)::

    from dump_decisions import dump_decisions
    n = dump_decisions(serve_engine, "decisions.jsonl")

As a demo, ``__main__`` runs a short device-placed two-pool serving
workload with a mid-run drain (set ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` to see real multi-device placement; it degrades to
same-device meshes on one) and dumps its full decision stream:

  PYTHONPATH=src python scripts/dump_decisions.py [out.jsonl]
"""
from __future__ import annotations

import json
import sys


def _jsonable(x):
    """Coerce decision payloads to JSON: numpy scalars/arrays, tuples-as-
    keys and device objects all appear in decision dicts; everything
    unknown degrades to ``repr`` rather than failing the export."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if hasattr(x, "item"):                      # numpy scalar
        return x.item()
    if hasattr(x, "tolist"):                    # numpy array
        return x.tolist()
    return repr(x)


def decision_records(engine):
    """Yield decision dicts from a ``ServeEngine`` (via its inner engine)
    or a bare ``Engine``, oldest first."""
    inner = getattr(engine, "engine", engine)
    for i, d in enumerate(inner.decisions):
        yield {"seq": i, **_jsonable(d)}


def dump_decisions(engine, path_or_file) -> int:
    """Write the engine's decision buffer as JSONL; returns the number of
    records written.  ``path_or_file`` is a filesystem path or any
    ``.write``-able (e.g. ``sys.stdout``)."""
    close = False
    f = path_or_file
    if not hasattr(f, "write"):
        f = open(path_or_file, "w", encoding="utf-8")
        close = True
    try:
        n = 0
        for rec in decision_records(engine):
            f.write(json.dumps(rec, separators=(",", ":")) + "\n")
            n += 1
        return n
    finally:
        if close:
            f.close()


def _demo(out):
    import numpy as np
    import jax
    from repro.configs import get_arch
    from repro.engine.serve import ServeEngine
    from repro.models import lm

    cfg = get_arch("gemma3-1b-smoke")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    devs = jax.devices()
    half = max(len(devs) // 2, 1)
    eng = ServeEngine(cfg, params, max_len=64, slots=2, pools=2,
                      prefill_chunk=4, decode_chunk=2,
                      placements={0: devs[:half], 1: devs[half:] or devs})
    rng = np.random.default_rng(0)
    # 3 requests over 2x2 slots: pool 1 keeps a free slot, so the mid-run
    # drain exercises the migration_dst decision path too
    reqs = [eng.submit(rng.integers(1, 100, size=n).tolist(), max_new=8)
            for n in (5, 9, 7)]
    for t in range(400):
        eng.tick()
        if t == 2:
            eng.drain_pool(0)       # mid-run drain: migration decisions
        if all(len(r.tokens) >= r.max_new for r in reqs):
            break
    n = dump_decisions(eng, out)
    kinds = {}
    for rec in decision_records(eng):
        k = rec.get("decision", "?")
        kinds[k] = kinds.get(k, 0) + 1
    print(f"# wrote {n} decisions; kinds: {kinds}", file=sys.stderr)


if __name__ == "__main__":
    _demo(open(sys.argv[1], "w", encoding="utf-8")
          if len(sys.argv) > 1 else sys.stdout)
