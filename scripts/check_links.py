"""Fail CI on broken intra-repo links in the documentation layer.

Scans the markdown files that make up the documentation surface (top-level
README.md, docs/, and the per-package READMEs), extracts every
``[text](target)`` link, and verifies that relative (or repo-rooted)
targets resolve to a real file or directory in the repo.  External links
(http/https/mailto) and pure anchors are skipped — this is an offline
check; CI must not flake on the network.

  python scripts/check_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

DOC_GLOBS = ("README.md", "docs/**/*.md", "src/repro/engine/README.md",
             "src/repro/kernels/README.md")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root: Path):
    for pattern in DOC_GLOBS:
        yield from sorted(root.glob(pattern))


def check_file(root: Path, md: Path) -> list:
    errors = []
    for m in LINK_RE.finditer(md.read_text(encoding="utf-8")):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]          # strip anchors
        if not path:
            continue
        # a leading "/" means repo-rooted, not filesystem-rooted (pathlib's
        # "/" operator would discard root for an absolute right operand)
        resolved = (root / path.lstrip("/")) if path.startswith("/") \
            else (md.parent / path)
        if not resolved.exists():
            errors.append(f"{md.relative_to(root)}: broken link -> {target}")
    return errors


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    root = root.resolve()
    files = list(doc_files(root))
    if not files:
        print("check_links: no documentation files found", file=sys.stderr)
        return 1
    errors = []
    for md in files:
        errors.extend(check_file(root, md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
