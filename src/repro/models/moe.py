"""Mixture-of-Experts FFN with first-class Reshape skew handling.

TPU-native adaptation of the paper's partitioning layer (DESIGN.md §2):

* The **partitioning logic** the paper mutates via control messages is here a
  jittable input — a :class:`RoutingPlan` mapping each *logical* expert to up
  to R *physical slots* with split fractions.  The controller swaps the plan
  between steps (fast control path, **no recompile**).
* Physical expert slots = ``num_experts + spare_slots``.  Spare slots live on
  (underloaded) EP ranks and receive *replicas* of hot experts — the paper's
  helper workers.  SBR = fractional split of a hot expert across slots;
  SBK = moving a whole expert to a different slot.
* Load metrics (per-slot/per-expert token counts, overflow drops) are computed
  inside the layer — the paper's metric collection (§3.7.9, 1–2 % overhead)
  becomes a free side output.
* Dispatch is sort-based (segment ranks) + scatter-add into a capacity-bucketed
  ``[slots, capacity, d]`` buffer, then dense per-slot matmuls (MXU-friendly),
  not GPU-style atomics.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


class RoutingPlan(NamedTuple):
    """Per-layer partitioning logic: logical expert -> physical slots."""
    slots: jnp.ndarray   # [L, E, R] int32 — physical slot of replica r
    cum: jnp.ndarray     # [L, E, R] f32  — cumulative split fractions (last=1)

    @property
    def num_replicas(self) -> int:
        return self.slots.shape[-1]


def identity_plan(cfg: ArchConfig, n_moe_layers: int) -> RoutingPlan:
    e, r = cfg.moe.num_experts, cfg.moe.max_replicas
    slots = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32)[None, :, None],
                             (n_moe_layers, e, r))
    cum = jnp.ones((n_moe_layers, e, r), jnp.float32)
    return RoutingPlan(slots, cum)


def num_slots(cfg: ArchConfig) -> int:
    return cfg.moe.num_experts + cfg.moe.spare_slots


def capacity(cfg: ArchConfig, tokens: int) -> int:
    m = cfg.moe
    return max(4, int(tokens * m.top_k * m.capacity_factor / m.num_experts))


def _gating_block(t: int, cap: int = 256) -> int:
    """Largest divisor of ``t`` that is <= cap (gating_pallas needs
    t % bt == 0; gcd(t, 256) only yields powers of two and collapses to a
    1-row block for odd t)."""
    for d in range(min(cap, t), 0, -1):
        if t % d == 0:
            return d
    return 1


def _hash_unit(idx):
    """Deterministic token -> [0,1) bucket (Knuth multiplicative hash)."""
    h = (idx.astype(jnp.uint32) * jnp.uint32(2654435761))
    return h.astype(jnp.float32) / jnp.float32(2 ** 32)


def route(router_w, x, plan_slots, plan_cum, cfg: ArchConfig, token_offset=0):
    """x [T,D] -> (slot [T,k], weight [T,k], probs [T,E], expert [T,k],
    counts [E] i32 from the fused gating kernel, or None)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x, router_w.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    counts = None
    if m.fused_gating:
        # Fused Pallas router: softmax + top-k + the Reshape load histogram
        # in one kernel, so metric collection costs zero extra passes.  The
        # kernel's outputs used here are integer (expert ids, counts); the
        # differentiable weights are re-gathered from `probs` below, so the
        # kernel itself needs no VJP rule.
        from repro.kernels.moe_gating.ops import gating
        impl = "pallas" if jax.default_backend() == "tpu" else "interpret"
        bt = _gating_block(x.shape[0])
        _, top_e, counts = gating(jax.lax.stop_gradient(logits), m.top_k,
                                  impl=impl, bt=bt)
        top_p = jnp.take_along_axis(probs, top_e, axis=-1)  # [T,k]
    else:
        top_p, top_e = jax.lax.top_k(probs, m.top_k)        # [T,k]
    weight = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Reshape SBR replica choice: hash token index into [0,1), pick replica by
    # the plan's cumulative fractions (the "partitioning logic").
    t_idx = token_offset + jnp.arange(x.shape[0])
    u = _hash_unit(t_idx)                                  # [T]
    cum_g = plan_cum[top_e]                                # [T,k,R]
    r = (cum_g[..., :-1] <= u[:, None, None]).sum(-1)      # [T,k]
    slot = jnp.take_along_axis(plan_slots[top_e], r[..., None], -1)[..., 0]
    return slot.astype(jnp.int32), weight, probs, top_e, counts


def dispatch_combine(x, slot, weight, expert_fn, n_slots: int, cap: int,
                     valid=None, fused: bool = False, impl: str = "auto"):
    """Sort-based capacity dispatch -> per-slot expert_fn -> weighted combine.

    x [T,D]; slot/weight [T,k]; ``valid`` [T,k] masks assignments owned by
    this shard (EP: foreign experts are some other rank's problem, not
    drops).  Returns (y [T,D], metrics dict).

    ``fused=True`` routes through the fused Pallas dispatch/combine kernel
    family (``kernels/moe_dispatch``): rank + capacity mask + bucketed
    scatter in one kernel instead of the argsort/searchsorted/scatter
    round-trip below, with bit-identical drop decisions and load metrics.
    """
    if fused:
        from repro.kernels.moe_dispatch.ops import \
            dispatch_combine as fused_dc
        return fused_dc(x, slot, weight, expert_fn, n_slots, cap,
                        valid=valid, impl=impl)
    t, d = x.shape
    k = slot.shape[1]
    tk = t * k
    flat_valid = (jnp.ones((tk,), bool) if valid is None
                  else valid.reshape(tk))
    # invalid assignments sort to a virtual segment past n_slots-1
    flat_slot = jnp.where(flat_valid, slot.reshape(tk), n_slots)

    # rank within slot segment via sort (no [TK, slots] one-hot materialized)
    sort_idx = jnp.argsort(flat_slot)
    sorted_slot = flat_slot[sort_idx]
    seg_start = jnp.searchsorted(sorted_slot, jnp.arange(n_slots + 1))
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - seg_start[
        jnp.minimum(sorted_slot, n_slots)]
    pos = jnp.zeros((tk,), jnp.int32).at[sort_idx].set(pos_sorted)

    keep = (pos < cap) & flat_valid
    dest = jnp.where(keep, flat_slot * cap + pos, n_slots * cap)  # drop bucket
    tok = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((n_slots * cap + 1, d), x.dtype).at[dest].add(
        x[tok] * keep[:, None].astype(x.dtype))
    buf = buf[:-1].reshape(n_slots, cap, d)

    out_buf = expert_fn(buf).reshape(n_slots * cap, d)     # [S,C,D] -> flat
    gathered = out_buf[jnp.where(keep, dest, 0)]           # [TK,D]
    contrib = gathered * (weight.reshape(tk, 1) * keep[:, None]).astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok].add(contrib)

    in_range = jnp.where(flat_valid, flat_slot, 0)
    slot_counts = jnp.zeros((n_slots,), jnp.int32).at[in_range].add(
        flat_valid.astype(jnp.int32))                      # routed (pre-drop)
    kept_counts = jnp.zeros((n_slots,), jnp.int32).at[in_range].add(
        keep.astype(jnp.int32))
    dropped = flat_valid.sum() - keep.sum()
    return y, {"slot_counts": slot_counts, "kept_counts": kept_counts,
               "dropped": dropped}


def moe_ffn_sharded(p, x, plan_slots, plan_cum, cfg: ArchConfig, mesh,
                    token_offset=0, tokens_sharded=True):
    """Expert-parallel MoE via full-manual ``shard_map`` (the production
    path; DESIGN.md §2 'TPU-idiomatic kernel choices').

    Experts are sharded over the ``model`` axis; tokens over data axes.  A
    device (row r, column c) owns row-r tokens and column-c expert slots, so
    dispatch is purely LOCAL (sort + scatter into the local capacity buffer)
    and the only collective is one psum over ``model`` for the combine —
    the same pattern as the dense-TP MLP all-reduce.  GSPMD never sees the
    scatter, avoiding its involuntary full rematerialization of the dispatch
    buffers (observed: 675 GB/device replicated under pure GSPMD).
    """
    from jax.sharding import PartitionSpec as P
    m = cfg.moe
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    s_total = num_slots(cfg)
    mdl = mesh.shape["model"]
    assert s_total % mdl == 0, (s_total, mdl)
    spr = s_total // mdl                       # slots per EP rank
    t_global = x.shape[0]
    dp = 1
    for a in da:
        dp *= mesh.shape[a]
    tokens_sharded = tokens_sharded and (t_global % dp == 0) and \
        (t_global // dp) > 0
    x_spec = P(da, None) if tokens_sharded else P(None, None)

    def local_fn(xl, router_w, wg, wu, wd, ps, pc):
        t_loc = xl.shape[0]
        if tokens_sharded and da:
            row = jax.lax.axis_index(da[0])
            for a in da[1:]:
                row = row * mesh.shape[a] + jax.lax.axis_index(a)
            base = token_offset + row * t_loc
        else:
            base = token_offset
        slot, weight, probs, top_e, r_counts = route(router_w, xl, ps, pc,
                                                     cfg, base)
        col = jax.lax.axis_index("model")
        lo = col * spr
        mine = (slot >= lo) & (slot < lo + spr)
        local_slot = jnp.where(mine, slot - lo, 0)     # masked by `valid`
        cap = capacity(cfg, t_loc)

        def expert_fn(buf):                            # [spr, C, D]
            g = jax.nn.silu(jnp.einsum("scd,sdf->scf", buf,
                                       wg.astype(buf.dtype)))
            u = jnp.einsum("scd,sdf->scf", buf, wu.astype(buf.dtype))
            return jnp.einsum("scf,sfd->scd", g * u, wd.astype(buf.dtype))

        y, met = dispatch_combine(xl, local_slot.astype(jnp.int32),
                                  jnp.where(mine, weight, 0.0),
                                  expert_fn, spr, cap, valid=mine,
                                  fused=m.fused_dispatch)
        y = jax.lax.psum(y, "model")
        slot_counts = met["kept_counts"]
        routed = met["slot_counts"]
        dropped = (routed - slot_counts).sum()
        if da:
            dropped = jax.lax.psum(dropped, da)
        e_counts = r_counts if r_counts is not None else jnp.zeros(
            (m.num_experts,), jnp.int32).at[top_e.reshape(-1)].add(1)
        if da:
            e_counts = jax.lax.psum(e_counts, da)
            slot_counts = jax.lax.psum(slot_counts, da)
        f = e_counts.astype(jnp.float32) / jnp.maximum(
            e_counts.sum().astype(jnp.float32), 1.0)
        pbar = probs.mean(0)
        if da:
            pbar = jax.lax.pmean(pbar, da)
        aux = m.num_experts * jnp.sum(f * pbar)
        rz = jnp.mean(jnp.square(jax.nn.logsumexp(
            jnp.log(probs + 1e-9), axis=-1)))
        if da:
            rz = jax.lax.pmean(rz, da)
        return y, slot_counts, e_counts, dropped, aux, rz

    y, slot_counts, e_counts, dropped, aux, rz = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  P(None, None), P(None, None)),
        out_specs=(x_spec, P("model"), P(None), P(), P(), P()),
        axis_names=set(mesh.axis_names),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"],
      plan_slots, plan_cum)
    return y, {"slot_counts": slot_counts, "kept_counts": slot_counts,
               "dropped": dropped, "aux_loss": aux,
               "expert_counts": e_counts, "router_z": rz}


def moe_ffn_a2a(p, x, plan_slots, plan_cum, cfg: ArchConfig, mesh,
                token_offset=0):
    """Beyond-paper §Perf variant: full-DP activations (batch sharded over
    data x model) + true all-to-all expert parallelism.

    Each device owns T_loc tokens and spr expert slots.  Tokens are bucketed
    per destination EP rank, exchanged with ``lax.all_to_all`` over
    ``model``, FFN'd locally, and returned — per-device collective bytes are
    ~2 * T_loc * k * D * (m-1)/m, an order of magnitude below the TP-psum
    scheme whose all-reduce moves every token's full activation twice per
    layer regardless of routing sparsity."""
    from jax.sharding import PartitionSpec as P
    m_cfg = cfg.moe
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mdl = mesh.shape["model"]
    s_total = num_slots(cfg)
    spr = s_total // mdl
    t_global = x.shape[0]
    all_axes = da + ("model",)
    dpm = 1
    for a in all_axes:
        dpm *= mesh.shape[a]
    sharded = t_global % dpm == 0 and t_global >= dpm
    x_spec = P(all_axes, None) if sharded else P(None, None)

    def local_fn(xl, router_w, wg, wu, wd, ps, pc):
        t_loc, d = xl.shape
        base = token_offset
        if sharded:
            idx = jax.lax.axis_index(all_axes[0])
            for a in all_axes[1:]:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            base = token_offset + idx * t_loc
        slot, weight, probs, top_e, r_counts = route(router_w, xl, ps, pc,
                                                     cfg, base)
        col_of = (slot // spr).astype(jnp.int32)          # dest EP rank
        tk = t_loc * m_cfg.top_k
        flat_col = col_of.reshape(tk)
        flat_slot = slot.reshape(tk)
        flat_w = weight.reshape(tk)
        tok = jnp.repeat(jnp.arange(t_loc), m_cfg.top_k)

        # bucket per destination column (capacity-bounded, sort-based rank;
        # fused: the same rank/mask/scatter in one dispatch kernel)
        cap_s = max(4, int(tk * m_cfg.capacity_factor / mdl))
        if m_cfg.fused_dispatch:
            from repro.kernels.moe_dispatch import ops as _dops
            all_valid = jnp.ones((t_loc, m_cfg.top_k), jnp.int32)
            bt = _dops.block_rows(t_loc)
            send_x3, rank2, keep2, _, _ = _dops.dispatch(
                xl, jnp.ones((t_loc, m_cfg.top_k), jnp.float32), col_of,
                all_valid, mdl, cap_s, "auto", bt)
            pos = rank2.reshape(tk)
            keep = keep2.reshape(tk) != 0
            dest = jnp.where(keep, flat_col * cap_s + pos, mdl * cap_s)
        else:
            sort_idx = jnp.argsort(flat_col)
            sorted_col = flat_col[sort_idx]
            seg = jnp.searchsorted(sorted_col, jnp.arange(mdl))
            pos_sorted = jnp.arange(tk, dtype=jnp.int32) - seg[sorted_col]
            pos = jnp.zeros((tk,), jnp.int32).at[sort_idx].set(pos_sorted)
            keep = pos < cap_s
            dest = jnp.where(keep, flat_col * cap_s + pos, mdl * cap_s)
            send_x = jnp.zeros((mdl * cap_s + 1, d), xl.dtype).at[dest].set(
                xl[tok])
            send_x3 = send_x[:-1].reshape(mdl, cap_s, d)
        send_slot = jnp.full((mdl * cap_s + 1,), -1, jnp.int32).at[dest].set(
            jnp.where(keep, flat_slot, -1))
        # exchange: [m, C, D] -> every column receives my bucket for it
        rx = jax.lax.all_to_all(send_x3, "model", split_axis=0,
                                concat_axis=0, tiled=False)
        rs = jax.lax.all_to_all(send_slot[:-1].reshape(mdl, cap_s),
                                "model", split_axis=0, concat_axis=0,
                                tiled=False)
        rx = rx.reshape(mdl * cap_s, d)
        rs_flat = rs.reshape(mdl * cap_s)
        col = jax.lax.axis_index("model")
        local_slot = jnp.where(rs_flat >= 0, rs_flat - col * spr, 0)
        valid = (rs_flat >= 0)

        def expert_fn(buf):                                # [spr, C2, D]
            g = jax.nn.silu(jnp.einsum("scd,sdf->scf", buf,
                                       wg.astype(buf.dtype)))
            u = jnp.einsum("scd,sdf->scf", buf, wu.astype(buf.dtype))
            return jnp.einsum("scf,sfd->scd", g * u, wd.astype(buf.dtype))

        cap2 = max(4, int(mdl * cap_s * m_cfg.capacity_factor / spr))
        y_rx, met = dispatch_combine(rx, local_slot[:, None],
                                     valid[:, None].astype(jnp.float32),
                                     expert_fn, spr, cap2,
                                     valid=valid[:, None],
                                     fused=m_cfg.fused_dispatch)
        # return path + weighted combine at the source
        y_back = jax.lax.all_to_all(y_rx.reshape(mdl, cap_s, d), "model",
                                    split_axis=0, concat_axis=0, tiled=False)
        if m_cfg.fused_dispatch:
            y = _dops.combine(y_back, weight.astype(jnp.float32), col_of,
                              rank2, keep2, all_valid, "auto", bt)
            y = y.astype(xl.dtype)
        else:
            y_back = y_back.reshape(mdl * cap_s, d)
            gathered = y_back[jnp.where(keep, dest, 0)]
            y = jnp.zeros((t_loc, d), xl.dtype).at[tok].add(
                gathered * (flat_w * keep)[:, None].astype(xl.dtype))

        # metrics (global): slot counts live on the expert's column
        slot_counts = met["kept_counts"]
        if da:
            slot_counts = jax.lax.psum(slot_counts, da)
        e_counts = r_counts if r_counts is not None else jnp.zeros(
            (m_cfg.num_experts,), jnp.int32).at[top_e.reshape(-1)].add(1)
        e_counts = jax.lax.psum(e_counts, all_axes if sharded else da) \
            if (da or sharded) else e_counts
        dropped = (tk - keep.sum()) + met["dropped"]
        dropped = jax.lax.psum(dropped, all_axes) if sharded else dropped
        f = e_counts.astype(jnp.float32) / jnp.maximum(
            e_counts.sum().astype(jnp.float32), 1.0)
        pbar = probs.mean(0)
        pbar = jax.lax.pmean(pbar, all_axes) if sharded else pbar
        aux = m_cfg.num_experts * jnp.sum(f * pbar)
        rz = jnp.mean(jnp.square(jax.nn.logsumexp(
            jnp.log(probs + 1e-9), axis=-1)))
        rz = jax.lax.pmean(rz, all_axes) if sharded else rz
        return y, slot_counts, e_counts, dropped, aux, rz

    y, slot_counts, e_counts, dropped, aux, rz = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  P(None, None), P(None, None)),
        out_specs=(x_spec, P("model"), P(None), P(), P(), P()),
        axis_names=set(mesh.axis_names),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"],
      plan_slots, plan_cum)
    return y, {"slot_counts": slot_counts, "kept_counts": slot_counts,
               "dropped": dropped, "aux_loss": aux,
               "expert_counts": e_counts, "router_z": rz}


def moe_ffn(p, x, plan_slots, plan_cum, cfg: ArchConfig, token_offset=0,
            mesh=None, tokens_sharded=True, layout: str = "tp"):
    """Full MoE FFN.  p: dict(router, w_gate [S,D,F], w_up, w_down [S,F,D]).

    Returns (y [T,D], metrics).  metrics includes the Reshape load metric phi
    (per-slot token counts) and the aux load-balance loss.
    """
    if mesh is not None and layout == "dp":
        return moe_ffn_a2a(p, x, plan_slots, plan_cum, cfg, mesh,
                           token_offset)
    if mesh is not None:
        return moe_ffn_sharded(p, x, plan_slots, plan_cum, cfg, mesh,
                               token_offset, tokens_sharded)
    m = cfg.moe
    t = x.shape[0]
    slot, weight, probs, top_e, r_counts = route(
        p["router"], x, plan_slots, plan_cum, cfg, token_offset)
    cap = capacity(cfg, t)
    s = num_slots(cfg)

    def expert_fn(buf):                                    # [S,C,D]
        g = jax.nn.silu(jnp.einsum("scd,sdf->scf", buf,
                                   p["w_gate"].astype(buf.dtype)))
        u = jnp.einsum("scd,sdf->scf", buf, p["w_up"].astype(buf.dtype))
        return jnp.einsum("scf,sfd->scd", g * u, p["w_down"].astype(buf.dtype))

    y, metrics = dispatch_combine(x, slot, weight, expert_fn, s, cap,
                                  fused=m.fused_dispatch)

    # Switch-style load-balance aux loss over *logical* experts.  With fused
    # gating the histogram comes straight from the kernel.
    e_counts = r_counts.astype(jnp.float32) if r_counts is not None else \
        jnp.zeros((m.num_experts,), jnp.float32).at[
            top_e.reshape(-1)].add(1.0)
    f = e_counts / (t * m.top_k)
    pbar = probs.mean(0)
    metrics["aux_loss"] = m.num_experts * jnp.sum(f * pbar)
    metrics["expert_counts"] = e_counts.astype(jnp.int32)
    metrics["router_z"] = jnp.mean(
        jnp.square(jax.nn.logsumexp(jnp.log(probs + 1e-9), axis=-1)))
    return y, metrics
