"""Declarative parameter system.

A model is declared as a nested dict of ``ParamDef``s (shape + logical axes +
init law).  From one declaration we derive (a) initialized parameter pytrees,
(b) ``jax.ShapeDtypeStruct`` trees for allocation-free dry-run lowering, and
(c) ``PartitionSpec`` trees via the logical-axis rules in
``repro.runtime.sharding`` — a single source of truth, no bookkeeping drift.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | embed
    dtype: Any = jnp.float32

    def fan_in(self) -> int:
        # last-but-one dim is the contraction dim for our matmuls
        return self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]


def stacked(n: int, d: ParamDef) -> ParamDef:
    """Prepend a layer-stacking dim (logical axis 'layers')."""
    return ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.dtype)


def is_def_tree_leaf(x) -> bool:
    return isinstance(x, ParamDef)


def map_defs(fn, defs):
    return jax.tree.map(fn, defs, is_leaf=is_def_tree_leaf)


def init_params(defs, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def_tree_leaf)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        elif d.init == "embed":
            out.append(jax.random.normal(k, d.shape, dtype) * 0.02)
        else:
            scale = 1.0 / math.sqrt(max(1, d.fan_in()))
            out.append(jax.random.normal(k, d.shape, dtype) * scale)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=jnp.float32):
    """ShapeDtypeStructs — for .lower() without allocating anything."""
    return map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs)


def param_specs(defs, rules: Dict[str, Optional[str]]):
    """PartitionSpec tree from logical-axis -> mesh-axis rules."""
    from jax.sharding import PartitionSpec as P

    def spec(d: ParamDef):
        return P(*[rules.get(a) if a is not None else None for a in d.axes])

    return map_defs(spec, defs)
