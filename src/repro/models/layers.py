"""Shared neural layers: RMSNorm, RoPE (incl. M-RoPE), gated MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, gamma, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return out * gamma.astype(x.dtype)


def _rope_angles(positions, head_dim, theta):
    """positions [..., S] -> (cos, sin) [..., S, head_dim/2]."""
    freqs = theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                      / (head_dim // 2))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta=10000.0):
    """x [B,S,H,hd]; positions [B,S] (int).  Rotate-half convention."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)    # [B,S,hd/2]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# Qwen2-VL multimodal rotary: the head_dim/2 frequency dims are partitioned
# into 3 sections driven by (t, h, w) position ids respectively.
MROPE_SECTIONS = (2, 3, 3)   # ratios; scaled to head_dim//2 at call time


def apply_mrope(x, positions3, theta=1_000_000.0):
    """x [B,S,H,hd]; positions3 [B,S,3] (t,h,w ids)."""
    hd = x.shape[-1]
    half = hd // 2
    total = sum(MROPE_SECTIONS)
    bounds = []
    acc = 0
    for s in MROPE_SECTIONS[:-1]:
        acc += round(half * s / total)
        bounds.append(acc)
    # section id per frequency index
    sec = jnp.zeros((half,), jnp.int32)
    for i, b in enumerate(bounds):
        sec = sec + (jnp.arange(half) >= b).astype(jnp.int32)
    # pick the position component per frequency
    pos = jnp.take_along_axis(
        positions3[..., None, :], sec[None, None, :, None], axis=-1
    )[..., 0]                                        # [B,S,half]
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """Gated MLP: silu(x@Wg) * (x@Wu) @ Wd."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype)))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", g * u, w_down.astype(x.dtype))


def sinusoidal_positions(seq, dim):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / dim))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
