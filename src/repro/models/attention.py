"""GQA attention.

The training/prefill path is a *chunked online-softmax* implementation (a
flash-attention-equivalent in pure jnp, O(S·chunk) memory instead of O(S²)) —
this is both what the CPU dry-run lowers and the numerical oracle for the
Pallas TPU kernel in ``repro.kernels.flash_attention``.  Supports causal,
sliding-window (gemma3 local layers), cross-attention (whisper), and
single-token decode against a (possibly rolling) KV cache.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(k, n_rep: int):
    """[B,S,KH,hd] -> [B,S,KH*n_rep,hd]."""
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd)).reshape(
        b, s, kh * n_rep, hd)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      q_offset=0, kv_chunk: int = 1024):
    """q [B,Sq,H,hd]; k,v [B,Sk,KH,hd].  Online-softmax over KV chunks.

    ``q_offset``: absolute position of q[0] relative to k[0] (prefill: 0 with
    Sq == Sk; decode: pos).  ``window``: sliding window size (None = full).
    """
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    q = q * (hd ** -0.5)
    k = repeat_kv(k, h // kh)
    v = repeat_kv(v, h // kh)

    kv_chunk = min(kv_chunk, sk)
    n_chunks = -(-sk // kv_chunk)
    pad = n_chunks * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, h, hd).transpose(1, 0, 3, 2, 4)

    qt = q.transpose(0, 2, 1, 3)                      # [B,H,Sq,hd]
    q_pos = q_offset + jnp.arange(sq)                 # absolute q positions

    def body(carry, inputs):
        m, l, acc, idx = carry
        kb, vb = inputs                               # [B,H,C,hd]
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qt, kb,
                            preferred_element_type=jnp.float32)
        mask = (k_pos[None, :] < sk)                  # padding
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, 0), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B,Sq,H,hd]


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None,
                     rolling: bool = False):
    """Single-token decode.  q [B,1,H,hd]; caches [B,Smax,KH,hd]; ``pos`` is
    the absolute position of the new token (already written to the cache).

    ``rolling``: cache stores entries at (abs_pos % Smax) — used for
    sliding-window layers where Smax == window.
    """
    b, _, h, hd = q.shape
    smax, kh = k_cache.shape[1], k_cache.shape[2]
    if k_cache.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        k_cache = k_cache.astype(jnp.bfloat16)   # fp8 KV cache dequant
        v_cache = v_cache.astype(jnp.bfloat16)
    k = repeat_kv(k_cache, h // kh)
    v = repeat_kv(v_cache, h // kh)
    q = q * (hd ** -0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    idx = jnp.arange(smax)
    if rolling:
        # entries idx hold absolute positions p with p % smax == idx and
        # p <= pos and p > pos - smax -> all entries valid once warm; mask
        # the not-yet-written ones when pos+1 < smax.
        valid = idx <= pos if True else None
        valid = jnp.where(pos + 1 >= smax, jnp.ones_like(idx, bool), idx <= pos)
    else:
        valid = idx <= pos
        if window is not None:
            valid = valid & (idx > pos - window)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return out.astype(q.dtype)
