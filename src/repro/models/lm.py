"""Unified language model over heterogeneous layer patterns.

Layers are grouped by block type into *stacked* parameter groups and executed
as ``lax.scan`` runs (HLO size independent of depth — 94-layer qwen3 compiles
as fast as 6-layer whisper).  Heterogeneous patterns (gemma3 5:1 local:global,
zamba2 mamba + shared-attn) become consecutive runs over slices of the
per-type stacks; ``shared_attn`` keeps a single unstacked weight copy but
per-occurrence KV caches.
"""
from __future__ import annotations

import itertools
from collections import defaultdict
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models.blocks import BLOCKS
from repro.models.layers import rms_norm, sinusoidal_positions
from repro.models.params import (ParamDef, abstract_params, init_params,
                                 map_defs, param_specs, stacked)


# ----------------------------------------------------------------- structure

def pattern_runs(cfg: ArchConfig):
    """[(block_type, count, per-type offset), ...] over cfg.pattern."""
    runs, offsets = [], defaultdict(int)
    for t, grp in itertools.groupby(cfg.pattern):
        c = len(list(grp))
        runs.append((t, c, offsets[t]))
        offsets[t] += c
    return runs


def type_counts(cfg: ArchConfig) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for t in cfg.pattern:
        counts[t] += 1
    return dict(counts)


def model_defs(cfg: ArchConfig) -> Dict[str, Any]:
    d = {"embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           "embed"),
         "final_ln": ParamDef((cfg.d_model,), ("embed",), "ones")}
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    for t, n in type_counts(cfg).items():
        bd = BLOCKS[t]["defs"](cfg)
        if t == "shared_attn":
            d[t] = bd                      # single shared copy
        else:
            d[t] = map_defs(lambda x: stacked(n, x), bd)
    if cfg.enc_layers:
        enc = BLOCKS["enc"]["defs"](cfg)
        d["enc"] = map_defs(lambda x: stacked(cfg.enc_layers, x), enc)
        d["enc_ln"] = ParamDef((cfg.d_model,), ("embed",), "ones")
    return d


def init(cfg: ArchConfig, key, dtype=jnp.float32):
    return init_params(model_defs(cfg), key, dtype)


def abstract(cfg: ArchConfig, dtype=jnp.float32):
    return abstract_params(model_defs(cfg), dtype)


def specs(cfg: ArchConfig, rules: Dict[str, Optional[str]]):
    return param_specs(model_defs(cfg), rules)


def n_moe_layers(cfg: ArchConfig) -> int:
    return type_counts(cfg).get("moe", 0)


def _slice_leaves(tree, off: int, count: int):
    return jax.tree.map(lambda x: jax.lax.slice_in_dim(x, off, off + count), tree)


# ------------------------------------------------------------------- forward

def _make_ctx(cfg: ArchConfig, b: int, s: int, batch: Dict[str, Any],
              impl: str, token_offset, mesh=None,
              tokens_sharded=True, layout="tp") -> Dict[str, Any]:
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ctx = {"cfg": cfg, "positions": pos, "impl": impl,
           "token_offset": token_offset, "moe_metrics": [],
           "mesh": mesh, "tokens_sharded": tokens_sharded,
           "layout": layout}
    if cfg.mrope:
        p3 = batch.get("positions3")
        if p3 is None:
            p3 = jnp.broadcast_to(pos[..., None], (b, s, 3))
        ctx["positions3"] = p3
    return ctx


def _run_stack(x, params, cfg, ctx, plan, remat: str):
    """Execute the layer pattern; returns (x, stacked-moe-metrics list)."""
    all_metrics = []
    mesh, act_spec = ctx.get("mesh"), ctx.get("act_spec")

    def constrain(h):
        if mesh is not None and act_spec is not None:
            from jax.sharding import NamedSharding
            return jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, act_spec))
        return h

    def wrap(fn):
        if remat == "full":
            return jax.checkpoint(fn)
        if remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots)
        return fn

    for t, count, off in pattern_runs(cfg):
        apply = BLOCKS[t]["apply"]
        if t == "shared_attn":
            fn = wrap(lambda p, h: apply(p, h, ctx))
            for _ in range(count):
                x = fn(params[t], x)
        elif t == "moe":
            p_run = _slice_leaves(params[t], off, count)
            ps = jax.lax.slice_in_dim(plan.slots, off, off + count)
            pc = jax.lax.slice_in_dim(plan.cum, off, off + count)

            def moe_body(h, inp):
                p_l, ps_l, pc_l = inp
                ctx_l = dict(ctx, plan_slots=ps_l, plan_cum=pc_l,
                             moe_metrics=[])
                h = wrap(lambda p, hh: apply(p, hh, ctx_l))(p_l, h)
                return h, ctx_l["moe_metrics"][0]

            x, metrics = jax.lax.scan(moe_body, x, (p_run, ps, pc))
            all_metrics.append(metrics)
        else:
            p_run = _slice_leaves(params[t], off, count)

            def body(h, p_l):
                return wrap(lambda p, hh: apply(p, hh, ctx))(p_l, h), None

            x, _ = jax.lax.scan(body, x, p_run)
        x = constrain(x)
    return x, all_metrics


def encode(params, frames, cfg: ArchConfig, impl="jnp"):
    """Whisper encoder over (stubbed) frame embeddings [B,S,D]."""
    b, s, _ = frames.shape
    x = frames + sinusoidal_positions(s, cfg.d_model)[None].astype(frames.dtype)
    ctx = {"cfg": cfg, "positions": jnp.broadcast_to(jnp.arange(s)[None],
                                                     (b, s)), "impl": impl}

    def body(h, p_l):
        return BLOCKS["enc"]["apply"](p_l, h, ctx), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_ln"], cfg.norm_eps)


def forward(params, batch: Dict[str, Any], cfg: ArchConfig, *,
            plan=None, impl: str = "jnp", token_offset=0,
            remat: str = "none", mesh=None, act_spec=None,
            tokens_sharded=True, layout: str = "tp"):
    """batch: tokens [B,S] (+ frames for audio, positions3 for vlm).
    Returns (logits [B,S,V], aux dict with moe metrics)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(jnp.bfloat16)
    if mesh is not None and act_spec is not None:
        from jax.sharding import NamedSharding
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, act_spec))
    ctx = _make_ctx(cfg, b, s, batch, impl, token_offset, mesh,
                    tokens_sharded, layout)
    ctx["act_spec"] = act_spec
    if cfg.enc_layers:
        enc_out = encode(params, batch["frames"].astype(jnp.bfloat16), cfg,
                         impl)
        ctx["enc_out"] = enc_out
    if plan is None and n_moe_layers(cfg):
        plan = moe_lib.identity_plan(cfg, n_moe_layers(cfg))
    x, moe_metrics = _run_stack(x, params, cfg, ctx, plan, remat)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    aux: Dict[str, Any] = {}
    if moe_metrics:
        # one stacked entry per moe run; concat over layers
        cat = {k: jnp.concatenate([m[k][None] if m[k].ndim == 0 else m[k]
                                   for m in moe_metrics], axis=0)
               for k in moe_metrics[0]}
        aux["moe"] = cat
    return logits.astype(jnp.float32), aux


# -------------------------------------------------------------------- decode

def init_cache(cfg: ArchConfig, batch: int, smax: int, kv_dtype=None):
    caches = {}
    for t, n in type_counts(cfg).items():
        mk = BLOCKS[t]["cache"]
        if mk is None:
            continue
        one = mk(cfg, batch, smax, kv_dtype) if t in (
            "attn", "local", "moe", "shared_attn", "dec") else mk(
            cfg, batch, smax)
        caches[t] = jax.tree.map(
            lambda x: jnp.zeros((n,) + x.shape, x.dtype), one)
    return {"caches": caches, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, state, token, cfg: ArchConfig, *, plan=None,
                impl: str = "jnp", mesh=None, tokens_sharded=True):
    """token [B,1] int32; state from init_cache.  Returns (logits, state)."""
    pos = state["pos"]
    b = token.shape[0]
    x = params["embed"][token].astype(jnp.bfloat16)
    ctx = {"cfg": cfg, "pos": pos, "impl": impl, "token_offset": pos,
           "positions": jnp.broadcast_to(pos[None, None], (b, 1)),
           "moe_metrics": [], "mesh": mesh,
           "tokens_sharded": tokens_sharded}
    if cfg.mrope:
        ctx["positions3"] = jnp.broadcast_to(pos[None, None, None], (b, 1, 3))
    if plan is None and n_moe_layers(cfg):
        plan = moe_lib.identity_plan(cfg, n_moe_layers(cfg))
    caches = dict(state["caches"])
    for t, count, off in pattern_runs(cfg):
        decode = BLOCKS[t]["decode"]
        c_run = _slice_leaves(caches[t], off, count)
        if t == "shared_attn":
            def body_sa(h, c_l):
                h, c_new = decode(params[t], h, c_l, ctx)
                return h, c_new
            x, c_out = jax.lax.scan(body_sa, x, c_run)
        elif t == "moe":
            p_run = _slice_leaves(params[t], off, count)
            ps = jax.lax.slice_in_dim(plan.slots, off, off + count)
            pc = jax.lax.slice_in_dim(plan.cum, off, off + count)

            def body_moe(h, inp):
                p_l, c_l, ps_l, pc_l = inp
                ctx_l = dict(ctx, plan_slots=ps_l, plan_cum=pc_l,
                             moe_metrics=[])
                h, c_new = decode(p_l, h, c_l, ctx_l)
                return h, c_new
            x, c_out = jax.lax.scan(body_moe, x, (p_run, c_run, ps, pc))
        else:
            p_run = _slice_leaves(params[t], off, count)

            def body(h, inp):
                p_l, c_l = inp
                h, c_new = decode(p_l, h, c_l, ctx)
                return h, c_new
            x, c_out = jax.lax.scan(body, x, (p_run, c_run))
        caches[t] = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                full, new, off, axis=0), caches[t], c_out)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits[:, 0].astype(jnp.float32), {
        "caches": caches, "pos": pos + 1}
