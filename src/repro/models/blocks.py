"""Per-block-type parameter definitions and apply functions.

Block types (see configs.base): attn, local, moe, rwkv, mamba, shared_attn,
enc, dec.  Each type defines:
  defs(cfg)                         parameter declaration (ParamDef tree)
  apply(p, x, ctx)                  full-sequence forward (train / prefill)
  decode(p, x, cache, ctx)          one-token forward + updated cache slice
  init_cache(cfg, batch, smax)      per-layer cache pytree (ShapeDtypeStruct-able)
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models.layers import apply_mrope, apply_rope, rms_norm, swiglu
from repro.models.params import ParamDef

LORA_DIM = 64


def _attn_defs(cfg: ArchConfig, cross: bool = False) -> Dict[str, ParamDef]:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pre = "c" if cross else ""
    return {
        pre + "wq": ParamDef((d, h * hd), ("embed", "qkv")),
        pre + "wk": ParamDef((d, kh * hd), ("embed", "qkv")),
        pre + "wv": ParamDef((d, kh * hd), ("embed", "qkv")),
        pre + "wo": ParamDef((h * hd, d), ("qkv", "embed")),
    }


def _mlp_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((d, f), ("embed", "mlp")),
        "w_up": ParamDef((d, f), ("embed", "mlp")),
        "w_down": ParamDef((f, d), ("mlp", "embed")),
    }


def _rope(cfg: ArchConfig, x, ctx):
    if cfg.rope_theta <= 0:
        return x
    if cfg.mrope:
        return apply_mrope(x, ctx["positions3"], cfg.rope_theta)
    return apply_rope(x, ctx["positions"], cfg.rope_theta)


def _project_qkv(cfg, p, x):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(x.dtype)).reshape(
        b, s, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(x.dtype)).reshape(
        b, s, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(x.dtype)).reshape(
        b, s, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def _self_attention(cfg, p, x, ctx, *, causal=True, window=None):
    b, s, d = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    q = _rope(cfg, q, ctx)
    k = _rope(cfg, k, ctx)
    out = attn_lib.chunked_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(x.dtype))


def _attn_block_apply(p, x, ctx, *, window=None, causal=True):
    cfg = ctx["cfg"]
    h = x + _self_attention(cfg, p, rms_norm(x, p["ln1"], cfg.norm_eps), ctx,
                            causal=causal, window=window)
    return h + swiglu(rms_norm(h, p["ln2"], cfg.norm_eps),
                      p["w_gate"], p["w_up"], p["w_down"])


def _attn_cache(cfg: ArchConfig, batch: int, smax: int, kv_dtype=None):
    kh, hd = cfg.n_kv_heads, cfg.hd
    z = jnp.zeros
    dt = kv_dtype or jnp.bfloat16
    return {"k": z((batch, smax, kh, hd), dt),
            "v": z((batch, smax, kh, hd), dt)}


def _attn_block_decode(p, x, cache, ctx, *, window=None, rolling=False):
    """x [B,1,D]; cache {k,v [B,Smax,KH,hd]}; ctx['pos'] scalar."""
    cfg, pos = ctx["cfg"], ctx["pos"]
    xb = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, p, xb)
    q = _rope(cfg, q, ctx)
    k = _rope(cfg, k, ctx)
    smax = cache["k"].shape[1]
    widx = pos % smax if rolling else pos
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, widx, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, widx, 0, 0))
    out = attn_lib.decode_attention(q, k_cache, v_cache, pos,
                                    window=window, rolling=rolling)
    out = out.reshape(x.shape[0], 1, cfg.n_heads * cfg.hd)
    h = x + jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(x.dtype))
    h = h + swiglu(rms_norm(h, p["ln2"], cfg.norm_eps),
                   p["w_gate"], p["w_up"], p["w_down"])
    return h, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------- attn/local

def attn_defs(cfg):
    return {"ln1": ParamDef((cfg.d_model,), ("embed",), "ones"),
            "ln2": ParamDef((cfg.d_model,), ("embed",), "ones"),
            **_attn_defs(cfg), **_mlp_defs(cfg)}


def attn_apply(p, x, ctx):
    return _attn_block_apply(p, x, ctx)


def attn_decode(p, x, cache, ctx):
    return _attn_block_decode(p, x, cache, ctx)


def local_apply(p, x, ctx):
    return _attn_block_apply(p, x, ctx, window=ctx["cfg"].window)


def local_decode(p, x, cache, ctx):
    # rolling window cache: smax == window
    return _attn_block_decode(p, x, cache, ctx, window=ctx["cfg"].window,
                              rolling=True)


# ----------------------------------------------------------------------- moe

def moe_defs(cfg):
    m = cfg.moe
    s = moe_lib.num_slots(cfg)
    d, f = cfg.d_model, m.expert_d_ff
    return {"ln1": ParamDef((cfg.d_model,), ("embed",), "ones"),
            "ln2": ParamDef((cfg.d_model,), ("embed",), "ones"),
            **_attn_defs(cfg),
            "router": ParamDef((d, m.num_experts), ("embed", None)),
            "w_gate": ParamDef((s, d, f), ("experts", "embed", None)),
            "w_up": ParamDef((s, d, f), ("experts", "embed", None)),
            "w_down": ParamDef((s, f, d), ("experts", None, "embed"))}


def moe_apply(p, x, ctx):
    cfg = ctx["cfg"]
    h = x + _self_attention(cfg, p, rms_norm(x, p["ln1"], cfg.norm_eps), ctx)
    b, s, d = h.shape
    plan_slots, plan_cum = ctx["plan_slots"], ctx["plan_cum"]
    flat = rms_norm(h, p["ln2"], cfg.norm_eps).reshape(b * s, d)
    y, metrics = moe_lib.moe_ffn(p, flat, plan_slots, plan_cum, cfg,
                                 token_offset=ctx.get("token_offset", 0),
                                 mesh=ctx.get("mesh"),
                                 tokens_sharded=ctx.get("tokens_sharded",
                                                        True),
                                 layout=ctx.get("layout", "tp"))
    ctx["moe_metrics"].append(metrics)
    return h + y.reshape(b, s, d)


def _moe_decode_impl(p, x, cache, ctx):
    cfg, pos = ctx["cfg"], ctx["pos"]
    xb = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, p, xb)
    q = _rope(cfg, q, ctx)
    k = _rope(cfg, k, ctx)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    out = attn_lib.decode_attention(q, k_cache, v_cache, pos)
    out = out.reshape(x.shape[0], 1, cfg.n_heads * cfg.hd)
    h = x + jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(x.dtype))
    b, s, d = h.shape
    flat = rms_norm(h, p["ln2"], cfg.norm_eps).reshape(b * s, d)
    y, metrics = moe_lib.moe_ffn(p, flat, ctx["plan_slots"], ctx["plan_cum"],
                                 cfg, token_offset=ctx.get("token_offset", 0),
                                 mesh=ctx.get("mesh"),
                                 tokens_sharded=ctx.get("tokens_sharded",
                                                        True),
                                 layout=ctx.get("layout", "tp"))
    ctx["moe_metrics"].append(metrics)
    return h + y.reshape(b, s, d), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------- rwkv

def rwkv_defs(cfg):
    d = cfg.d_model
    h, n = cfg.n_heads, cfg.hd
    f = cfg.d_ff
    return {
        "ln1": ParamDef((d,), ("embed",), "ones"),
        "ln2": ParamDef((d,), ("embed",), "ones"),
        "mu_r": ParamDef((d,), ("embed",), "zeros"),
        "mu_k": ParamDef((d,), ("embed",), "zeros"),
        "mu_v": ParamDef((d,), ("embed",), "zeros"),
        "mu_w": ParamDef((d,), ("embed",), "zeros"),
        "mu_g": ParamDef((d,), ("embed",), "zeros"),
        "wr": ParamDef((d, d), ("embed", "qkv")),
        "wk": ParamDef((d, d), ("embed", "qkv")),
        "wv": ParamDef((d, d), ("embed", "qkv")),
        "wg": ParamDef((d, d), ("embed", "qkv")),
        "w0": ParamDef((d,), ("embed",), "zeros"),
        "w_lora_a": ParamDef((d, LORA_DIM), ("embed", None)),
        "w_lora_b": ParamDef((LORA_DIM, d), (None, "embed")),
        "u": ParamDef((h, n), (None, None)),
        "ln_x": ParamDef((d,), ("embed",), "ones"),
        "wo": ParamDef((d, d), ("qkv", "embed")),
        "mu_ck": ParamDef((d,), ("embed",), "zeros"),
        "wck": ParamDef((d, f), ("embed", "mlp")),
        "wcv": ParamDef((f, d), ("mlp", "embed")),
        "wcr": ParamDef((d, d), ("embed", "qkv")),
    }


def _shift(x, x_prev_token=None):
    """Token shift: prepend previous-token row (zeros / carried state)."""
    pad = jnp.zeros_like(x[:, :1]) if x_prev_token is None else x_prev_token
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _rwkv_decay(p, xw):
    lora = jnp.einsum("bsd,dk->bsk", xw, p["w_lora_a"].astype(xw.dtype))
    lora = jnp.einsum("bsk,kd->bsd", jnp.tanh(lora),
                      p["w_lora_b"].astype(xw.dtype))
    return jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) +
                             lora.astype(jnp.float32)).clip(-8, 1.5)))


def rwkv_time_mix(p, x, ctx, x_prev=None, state=None):
    """x [B,S,D].  Returns (out, last_x, new_state)."""
    cfg = ctx["cfg"]
    b, s, d = x.shape
    h, n = cfg.n_heads, cfg.hd
    xs = _shift(x, x_prev)
    def mix(mu):
        return x + mu.astype(x.dtype) * (xs - x)
    from repro.kernels.rwkv6_scan.ops import rwkv6, rwkv6_decode_step
    r = jnp.einsum("bsd,dq->bsq", mix(p["mu_r"]), p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dq->bsq", mix(p["mu_k"]), p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dq->bsq", mix(p["mu_v"]), p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,dq->bsq", mix(p["mu_g"]), p["wg"].astype(x.dtype))
    w = _rwkv_decay(p, mix(p["mu_w"]))
    to_heads = lambda z: z.reshape(b, s, h, n).transpose(0, 2, 1, 3)
    u = p["u"].astype(jnp.float32)
    if s == 1 and state is not None:
        y, s_new = rwkv6_decode_step(
            to_heads(r)[:, :, 0], to_heads(k)[:, :, 0], to_heads(v)[:, :, 0],
            to_heads(w.astype(x.dtype))[:, :, 0], u, state)
        y = y[:, :, None]                     # [B,H,1,N]
    else:
        y, s_new = rwkv6(to_heads(r), to_heads(k), to_heads(v),
                         to_heads(w.astype(x.dtype)), u, s0=state,
                         chunk=cfg.ssm.chunk, impl=ctx.get("impl", "jnp"))
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps) * jax.nn.silu(g)
    out = jnp.einsum("bsq,qd->bsd", y, p["wo"].astype(x.dtype))
    return out, x[:, -1:], s_new


def rwkv_channel_mix(p, x, x_prev=None):
    xs = _shift(x, x_prev)
    xk = x + p["mu_ck"].astype(x.dtype) * (xs - x)
    r = jax.nn.sigmoid(jnp.einsum("bsd,dq->bsq", xk, p["wcr"].astype(x.dtype)))
    k = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk, p["wck"].astype(x.dtype))))
    return r * jnp.einsum("bsf,fd->bsd", k, p["wcv"].astype(x.dtype)), x[:, -1:]


def rwkv_apply(p, x, ctx):
    cfg = ctx["cfg"]
    tm, _, _ = rwkv_time_mix(p, rms_norm(x, p["ln1"], cfg.norm_eps), ctx)
    h = x + tm
    cm, _ = rwkv_channel_mix(p, rms_norm(h, p["ln2"], cfg.norm_eps))
    return h + cm


def rwkv_cache(cfg, batch, smax):
    h, n, d = cfg.n_heads, cfg.hd, cfg.d_model
    z = jnp.zeros
    return {"s": z((batch, h, n, n), jnp.float32),
            "x_tm": z((batch, 1, d), jnp.bfloat16),
            "x_cm": z((batch, 1, d), jnp.bfloat16)}


def rwkv_decode(p, x, cache, ctx):
    cfg = ctx["cfg"]
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    tm, last_x, s_new = rwkv_time_mix(
        p, xn, ctx, x_prev=cache["x_tm"].astype(xn.dtype), state=cache["s"])
    h = x + tm
    hn = rms_norm(h, p["ln2"], cfg.norm_eps)
    cm, last_cm = rwkv_channel_mix(p, hn, x_prev=cache["x_cm"].astype(hn.dtype))
    return h + cm, {"s": s_new, "x_tm": last_x.astype(cache["x_tm"].dtype),
                    "x_cm": last_cm.astype(cache["x_cm"].dtype)}


# --------------------------------------------------------------------- mamba

def mamba_defs(cfg):
    d = cfg.d_model
    ssm = cfg.ssm
    di = ssm.expand * d
    h = di // ssm.head_dim
    n = ssm.state_size
    conv_dim = di + 2 * n
    return {
        "ln": ParamDef((d,), ("embed",), "ones"),
        "in_proj": ParamDef((d, 2 * di + 2 * n + h), ("embed", "qkv")),
        "conv_w": ParamDef((ssm.conv_kernel, conv_dim), (None, "qkv")),
        "dt_bias": ParamDef((h,), (None,), "zeros"),
        "a_log": ParamDef((h,), (None,), "zeros"),
        "d_skip": ParamDef((h,), (None,), "zeros"),
        "norm": ParamDef((di,), ("qkv",), "ones"),
        "out_proj": ParamDef((di, d), ("qkv", "embed")),
    }


def _mamba_split(cfg, zxbcdt):
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    h = di // ssm.head_dim
    n = ssm.state_size
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt, di, h, n


def mamba_apply(p, x, ctx):
    cfg = ctx["cfg"]
    from repro.kernels.mamba2_ssd.ops import mamba2
    ssm = cfg.ssm
    b, s, d = x.shape
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", xn, p["in_proj"].astype(x.dtype))
    z, xbc, dt, di, h, n = _mamba_split(cfg, zxbcdt)
    # causal depthwise conv over (x,B,C)
    k = ssm.conv_kernel
    xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(xbc_pad[:, i:i + s] * p["conv_w"][i].astype(x.dtype)
               for i in range(k))
    conv = jax.nn.silu(conv)
    xs, bm, c = jnp.split(conv, [di, di + n], axis=-1)
    dt_full = jax.nn.softplus(dt.astype(jnp.float32) +
                              p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(b, s, h, ssm.head_dim).transpose(0, 2, 1, 3)
    y, _ = mamba2(xh, dt_full.transpose(0, 2, 1), a, bm, c,
                  p["d_skip"].astype(jnp.float32), chunk=ssm.chunk,
                  impl=ctx.get("impl", "jnp"))
    y = y.transpose(0, 2, 1, 3).reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return x + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))


def mamba_cache(cfg, batch, smax):
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    h = di // ssm.head_dim
    n = ssm.state_size
    z = jnp.zeros
    return {"conv": z((batch, ssm.conv_kernel - 1, di + 2 * n), jnp.bfloat16),
            "h": z((batch, h, ssm.head_dim, n), jnp.float32)}


def mamba_decode(p, x, cache, ctx):
    cfg = ctx["cfg"]
    from repro.kernels.mamba2_ssd.ops import mamba2_decode_step
    ssm = cfg.ssm
    b = x.shape[0]
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", xn, p["in_proj"].astype(x.dtype))
    z, xbc, dt, di, h, n = _mamba_split(cfg, zxbcdt)
    xbc = xbc[:, 0]                                     # [B, convdim]
    window = jnp.concatenate([cache["conv"].astype(x.dtype),
                              xbc[:, None]], axis=1)    # [B, K, convdim]
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(x.dtype))
    conv = jax.nn.silu(conv)
    xs, bm, c = jnp.split(conv, [di, di + n], axis=-1)
    dt_full = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                              p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(b, h, ssm.head_dim)
    y, h_new = mamba2_decode_step(xh, dt_full, a, bm, c,
                                  p["d_skip"].astype(jnp.float32), cache["h"])
    y = y.reshape(b, 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": window[:, 1:].astype(cache["conv"].dtype), "h": h_new}


# --------------------------------------------------------- shared_attn (zamba)

shared_attn_defs = attn_defs
shared_attn_apply = attn_apply
shared_attn_decode = attn_decode


# ------------------------------------------------------------- whisper enc/dec

def enc_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {"ln1": ParamDef((d,), ("embed",), "ones"),
            "ln2": ParamDef((d,), ("embed",), "ones"),
            **_attn_defs(cfg),
            "w_in": ParamDef((d, f), ("embed", "mlp")),
            "w_out": ParamDef((f, d), ("mlp", "embed"))}


def _plain_mlp(p, x):
    hdn = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype)))
    return jnp.einsum("bsf,fd->bsd", hdn, p["w_out"].astype(x.dtype))


def enc_apply(p, x, ctx):
    cfg = ctx["cfg"]
    h = x + _self_attention(cfg, p, rms_norm(x, p["ln1"], cfg.norm_eps), ctx,
                            causal=False)
    return h + _plain_mlp(p, rms_norm(h, p["ln2"], cfg.norm_eps))


def dec_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {"ln1": ParamDef((d,), ("embed",), "ones"),
            "ln_c": ParamDef((d,), ("embed",), "ones"),
            "ln2": ParamDef((d,), ("embed",), "ones"),
            **_attn_defs(cfg), **_attn_defs(cfg, cross=True),
            "w_in": ParamDef((d, f), ("embed", "mlp")),
            "w_out": ParamDef((f, d), ("mlp", "embed"))}


def _cross_attention(cfg, p, x, enc_out):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, p["cwq"].astype(x.dtype)).reshape(
        b, s, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,dq->bsq", enc_out,
                   p["cwk"].astype(x.dtype)).reshape(
        b, -1, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dq->bsq", enc_out,
                   p["cwv"].astype(x.dtype)).reshape(
        b, -1, cfg.n_kv_heads, cfg.hd)
    out = attn_lib.chunked_attention(q, k, v, causal=False)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd)
    return jnp.einsum("bsq,qd->bsd", out, p["cwo"].astype(x.dtype))


def dec_apply(p, x, ctx):
    cfg = ctx["cfg"]
    h = x + _self_attention(cfg, p, rms_norm(x, p["ln1"], cfg.norm_eps), ctx,
                            causal=True)
    h = h + _cross_attention(cfg, p, rms_norm(h, p["ln_c"], cfg.norm_eps),
                             ctx["enc_out"])
    return h + _plain_mlp(p, rms_norm(h, p["ln2"], cfg.norm_eps))


def dec_cache(cfg, batch, smax, kv_dtype=None):
    kh, hd = cfg.n_kv_heads, cfg.hd
    z = jnp.zeros
    dt = kv_dtype or jnp.bfloat16
    return {"k": z((batch, smax, kh, hd), dt),
            "v": z((batch, smax, kh, hd), dt),
            "ck": z((batch, cfg.enc_seq, kh, hd), jnp.bfloat16),
            "cv": z((batch, cfg.enc_seq, kh, hd), jnp.bfloat16)}


def dec_decode(p, x, cache, ctx):
    cfg, pos = ctx["cfg"], ctx["pos"]
    xb = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, p, xb)
    q = _rope(cfg, q, ctx)
    k = _rope(cfg, k, ctx)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    out = attn_lib.decode_attention(q, k_cache, v_cache, pos)
    out = out.reshape(x.shape[0], 1, cfg.n_heads * cfg.hd)
    h = x + jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(x.dtype))
    # cross attention against precomputed encoder K/V
    xq = rms_norm(h, p["ln_c"], cfg.norm_eps)
    b = x.shape[0]
    qc = jnp.einsum("bsd,dq->bsq", xq, p["cwq"].astype(x.dtype)).reshape(
        b, 1, cfg.n_heads, cfg.hd)
    co = attn_lib.decode_attention(qc, cache["ck"], cache["cv"],
                                   cache["ck"].shape[1] - 1)
    co = co.reshape(b, 1, cfg.n_heads * cfg.hd)
    h = h + jnp.einsum("bsq,qd->bsd", co, p["cwo"].astype(x.dtype))
    h = h + _plain_mlp(p, rms_norm(h, p["ln2"], cfg.norm_eps))
    return h, {"k": k_cache, "v": v_cache, "ck": cache["ck"], "cv": cache["cv"]}


BLOCKS: Dict[str, Dict[str, Any]] = {
    "attn": dict(defs=attn_defs, apply=attn_apply, decode=attn_decode,
                 cache=_attn_cache),
    "local": dict(defs=attn_defs, apply=local_apply, decode=local_decode,
                  cache=lambda cfg, b, smax, kv_dtype=None: _attn_cache(
                      cfg, b, min(smax, cfg.window), kv_dtype)),
    "moe": dict(defs=moe_defs, apply=moe_apply, decode=_moe_decode_impl,
                cache=_attn_cache),
    "rwkv": dict(defs=rwkv_defs, apply=rwkv_apply, decode=rwkv_decode,
                 cache=rwkv_cache),
    "mamba": dict(defs=mamba_defs, apply=mamba_apply, decode=mamba_decode,
                  cache=mamba_cache),
    "shared_attn": dict(defs=shared_attn_defs, apply=shared_attn_apply,
                        decode=shared_attn_decode, cache=_attn_cache),
    "enc": dict(defs=enc_defs, apply=enc_apply, decode=None, cache=None),
    "dec": dict(defs=dec_defs, apply=dec_apply, decode=dec_decode,
                cache=dec_cache),
}
