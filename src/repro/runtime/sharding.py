"""Sharding rules: logical param axes -> mesh axes, batch/cache specs.

Baseline layout (DESIGN.md §5): 2-D sharding — every big matrix splits its
output dim over ``model`` (Megatron-style TP via GSPMD propagation) and its
input/embed dim over ``data`` (+``pod``) (FSDP/ZeRO-style full sharding, so
104B-param command-r fits: params+grads+adam fp32 ~18 bytes/param over 512
chips ≈ 3.7 GB/chip).  Non-divisible dims fall back to replication per leaf
(e.g. whisper's vocab 51865).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models.params import ParamDef, is_def_tree_leaf, map_defs


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def logical_rules(mesh: Mesh, fsdp: bool = True) -> Dict[str, object]:
    return {
        "vocab": "model",
        "embed": data_axes(mesh) if fsdp else None,
        "qkv": "model",
        "mlp": "model",
        "experts": "model",
        "layers": None,
    }


def param_specs(cfg: ArchConfig, mesh: Mesh, fsdp: bool = True):
    """PartitionSpec tree with per-leaf divisibility fallback."""
    from repro.models.lm import model_defs
    rules = logical_rules(mesh, fsdp)

    def spec(d: ParamDef):
        parts = []
        for dim, ax in zip(d.shape, d.axes):
            target = rules.get(ax) if ax is not None else None
            if target is None:
                parts.append(None)
            elif dim % axis_size(mesh, target) == 0:
                parts.append(target)
            else:
                parts.append(None)           # non-divisible -> replicate
        return P(*parts)

    return map_defs(spec, model_defs(cfg))


def batch_specs(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                layout: str = "tp"):
    da = data_axes(mesh)
    if layout == "dp":
        full = da + ("model",)
        if shape.global_batch % axis_size(mesh, full) == 0:
            da = full
    dp = axis_size(mesh, da)
    seq_sharded = shape.global_batch < dp        # long_500k: batch of 1
    tok = P(None, da) if seq_sharded else P(da, None)
    out = {"tokens": tok}
    if cfg.enc_layers:
        out["frames"] = P(da, None, None) if not seq_sharded else P(None, None, None)
    if cfg.mrope:
        out["positions3"] = P(da, None, None) if not seq_sharded else \
            P(None, None, None)
    return out


def _model_dim_part(mesh: Mesh, *dims):
    """Pick the first dim (by index into ``dims``) divisible by |model|."""
    m = axis_size(mesh, "model")
    for i, d in enumerate(dims):
        if d % m == 0:
            return i
    return None


def act_spec_for(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                 layout: str = "tp"):
    """PartitionSpec for [B, S, D] activations under the given layout."""
    da = data_axes(mesh)
    if layout == "dp":
        full = da + ("model",)
        if shape.global_batch % axis_size(mesh, full) == 0:
            return P(full, None, None)
    if shape.global_batch < axis_size(mesh, da):
        return P(None, da, None)
    return P(da, None, None)


def cache_specs(cfg: ArchConfig, mesh: Mesh, shape: ShapeCfg, cache_tree,
                force_seq_shard: bool = False):
    """Spec tree matching lm.init_cache output.  decode_*: batch over data;
    long_500k (batch < dp): KV sequence over data, SSM state over model.
    ``force_seq_shard``: decode2d layout — weights 2-D sharded, cache
    sequence-sharded, batch replicated (weight-stationary decode)."""
    da = data_axes(mesh)
    dp = axis_size(mesh, da)
    seq_sharded = shape.global_batch < dp or force_seq_shard
    KV_TYPES = ("attn", "local", "moe", "shared_attn", "dec")

    def leaf_spec(path, x):
        btype = path[0].key if hasattr(path[0], "key") else str(path[0])
        shp = x.shape
        nd = len(shp)
        batch = None if seq_sharded else da
        if btype in KV_TYPES and nd == 5:     # [n, B, S, KH, hd]
            i = _model_dim_part(mesh, shp[3], shp[4])
            kv = [None, None]
            if i is not None:
                kv[i] = "model"
            seq = da if seq_sharded else None
            return P(None, batch, seq, *kv)
        if nd >= 4:                           # SSM states: [n,B,H,...] etc.
            i = _model_dim_part(mesh, *shp[2:])
            tail = [None] * (nd - 2)
            if i is not None:
                tail[i] = "model"
            return P(None, batch, *tail)
        if nd == 3:                           # x_tm/x_cm [n, B, D]
            return P(None, batch, None)
        return P(*([None] * nd))

    caches = jax.tree_util.tree_map_with_path(leaf_spec, cache_tree["caches"])
    return {"caches": caches, "pos": P()}


def pool_mesh(devices, tp: int = 1) -> Mesh:
    """A ("data", "model") mesh over an explicit device group — the unit a
    serve slot pool is *placed* on (``ServeEngine(placements=...)``).

    ``tp`` is the tensor-parallel degree within the pool: the trailing
    ``model`` axis gets ``tp`` devices and the leading ``data`` axis the
    rest, so ``param_specs``/``pool_specs`` rules apply unchanged.  The
    default ``tp=1`` keeps every matmul's reduction on one device, which is
    what preserves the serve engine's greedy bit-identicality guarantee
    across placements (a split reduction reorders float adds)."""
    devs = list(devices)
    assert devs, "pool_mesh needs at least one device"
    assert len(devs) % max(tp, 1) == 0, \
        f"{len(devs)} devices not divisible by tp={tp}"
    arr = np.asarray(devs, dtype=object).reshape(len(devs) // tp, tp)
    return Mesh(arr, ("data", "model"))


def pool_specs(mesh: Mesh, pool_tree):
    """Spec tree for a SlotPool's donated device state (cache rows, n-gram
    tables, positions, PRNG keys): every leaf is ``[slots, ...]``, so the
    slot dim shards over ``data`` when divisible (per-slot compute is
    independent — a slot-dim split never touches a reduction, so outputs
    stay bit-identical) and trailing dims of deep leaves shard over
    ``model`` when a dim divides (inert at the default tp=1).  Non-divisible
    leaves fall back to replication, the same per-leaf discipline as
    ``param_specs``."""
    da = data_axes(mesh)
    dp = axis_size(mesh, da)

    def leaf(x):
        nd = x.ndim
        lead = da if (da and x.shape[0] % dp == 0) else None
        tail = [None] * (nd - 1)
        if nd >= 3 and axis_size(mesh, "model") > 1:
            i = _model_dim_part(mesh, *x.shape[2:])
            if i is not None:
                tail[1 + i] = "model"
        return P(lead, *tail)

    return jax.tree.map(leaf, pool_tree)


def opt_state_specs(param_spec_tree):
    from repro.optim.adamw import OptState
    return OptState(param_spec_tree, param_spec_tree, P())


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
