"""Serve-step builder: single-token batched decode against KV/SSM caches
(what ``decode_32k`` / ``long_500k`` lower), plus a host-side batched
serving loop with prefill-as-decode and temperature sampling."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import lm
from repro.models import moe as moe_lib


def build_serve_step(cfg: ArchConfig, mesh=None, tokens_sharded=True):
    nl_moe = lm.n_moe_layers(cfg)

    def serve_step(params, state, token, plan_slots=None, plan_cum=None):
        plan = None
        if nl_moe and plan_slots is not None:
            plan = moe_lib.RoutingPlan(plan_slots, plan_cum)
        return lm.decode_step(params, state, token, cfg, plan=plan,
                              mesh=mesh, tokens_sharded=tokens_sharded)

    return serve_step


def abstract_serve_inputs(cfg: ArchConfig, shape: ShapeCfg, kv_dtype=None):
    """ShapeDtypeStruct stand-ins: cache at seq_len, one new token.
    eval_shape — a 550 GB KV cache must never materialize on the host."""
    cache_abs = jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len,
                              kv_dtype))
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return cache_abs, token


def sample(logits: jnp.ndarray, key, temperature: float = 0.8):
    if temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


class BatchedServer:
    """Serving front door for examples + tests — now a thin client of the
    engine layer: ``generate`` routes through
    :class:`repro.engine.ServeEngine` (continuous batching, chunked batched
    prefill, control plane between ticks).  The pre-engine loop — static
    batch, prefill one token per dispatch — survives as
    ``generate_static``: it is the benchmark baseline and the output-
    equivalence oracle for the engine path.

    Priority routing: ``pools`` > 1 spreads requests over several slot
    pools arbitrated by the engine's weighted-FRT objective, and
    ``class_pools`` (class name -> tuple of admissible pool ids) pins
    traffic classes to pools — e.g. reserve pool 0 for the interactive
    class while batch traffic shares the rest.  ``generate`` takes an
    optional per-prompt ``priorities`` list naming ``cfg.serve.classes``
    entries; ``submit`` exposes the streaming API with the same knobs."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 128,
                 slots: int = 4, prefill_chunk: int = 16,
                 decode_chunk: int = 4, spec_decode: bool = False,
                 pools: int = 1, class_pools: Optional[Dict] = None,
                 prefix_cache: bool = False, draft: Optional[str] = None,
                 draft_cfg: Optional[ArchConfig] = None, draft_params=None,
                 placements: Optional[Dict] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = slots
        self.prefill_chunk = prefill_chunk
        self.decode_chunk = decode_chunk
        self.spec_decode = spec_decode
        self.pools = pools
        self.class_pools = class_pools
        # cross-request prefix cache + exact-hit result cache (cfg.serve
        # knobs size it); greedy outputs stay bit-identical with it on
        self.prefix_cache = prefix_cache
        # draft-model proposer: "self" slices a truncated self-draft from
        # params, or pass an independent draft_cfg+draft_params (e.g. one
        # distilled by repro.engine.draft.distill_draft)
        self.draft = draft
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        # pool id -> device list / Mesh: device-placed slot pools (params
        # replicated or TP-sharded per pool, caches resident on the pool's
        # devices; see ServeEngine placements)
        self.placements = placements
        self._step = None                # static-path jit, built on demand
        self._engine = None

    def engine(self, seed: int = 0):
        from repro.engine.serve import ServeEngine
        if self._engine is None:
            self._engine = ServeEngine(
                self.cfg, self.params, max_len=self.max_len,
                slots=self.slots, prefill_chunk=self.prefill_chunk,
                decode_chunk=self.decode_chunk, seed=seed,
                spec_decode=self.spec_decode, pools=self.pools,
                class_pools=self.class_pools,
                prefix_cache=self.prefix_cache, draft=self.draft,
                draft_cfg=self.draft_cfg, draft_params=self.draft_params,
                placements=self.placements)
        return self._engine

    def submit(self, prompt, max_new: int = 16, temperature: float = 0.0,
               priority: Optional[str] = None, pool: Optional[int] = None):
        """Streaming API: queue one request on the engine and return the
        live :class:`repro.engine.Request` (drive with ``engine().tick()``
        or ``engine().run_until_done()``)."""
        return self.engine().submit(prompt, max_new, temperature,
                                    priority=priority, pool=pool)

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 priorities=None):
        # seed pins per-request sampling keys on every call (the cached
        # ServeEngine's own seed only covers requests submitted without one)
        return self.engine(seed).generate(prompts, max_new, temperature,
                                          seed=seed, priorities=priorities)

    def generate_static(self, prompts: np.ndarray, max_new: int = 16,
                        temperature: float = 0.0, seed: int = 0):
        """The old static loop: one decode dispatch per prompt token
        (prefill) and per generated token, whole batch in lockstep."""
        if self._step is None:
            self._step = jax.jit(build_serve_step(self.cfg))
        b, plen = prompts.shape
        state = lm.init_cache(self.cfg, b, self.max_len)
        logits = None
        for i in range(plen):
            logits, state = self._step(self.params, state,
                                       jnp.asarray(prompts[:, i:i + 1]))
        key = jax.random.PRNGKey(seed)
        out = []
        tok = sample(logits, key, temperature)[:, None]
        for i in range(max_new):
            out.append(np.asarray(tok))
            logits, state = self._step(self.params, state, tok)
            key, sub = jax.random.split(key)
            tok = sample(logits, sub, temperature)[:, None]
        return np.concatenate(out, axis=1)
