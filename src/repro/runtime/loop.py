"""Interactive training loop — the Amber worker on the ML runtime.

Granulated iteration (paper §2.4.3): the loop polls the controller mailbox
between *microbatches*, so Pause/Inspect/Update take effect within one
microbatch; while paused it keeps answering Inspect/Update (§2.4.4).
Local breakpoints are checked on every microbatch's metrics; global COUNT
breakpoints accumulate across shards/steps.  Reshape (MoEReshaper) observes
the free load metrics and swaps the routing plan + migrates expert state
between steps.  Fault tolerance: checkpoints carry the data-iterator state
and the control-replay log; ``TrainLoop.recover`` restores and re-applies
logged messages at their recorded (step, microbatch) points -> bit-exact
continuation (§2.6.2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ArchConfig
from repro.core.breakpoints import GlobalCountBreakpoint, LocalBreakpoint
from repro.core.controller import Controller, ReplayingController
from repro.core.reshape_moe import MoEReshaper
from repro.data.synthetic import TokenStream
from repro.models import lm
from repro.models import moe as moe_lib
from repro.runtime.train import TrainHyper, build_grad_step, make_state


@dataclasses.dataclass
class LoopConfig:
    microbatches: int = 2
    ckpt_every: int = 0                  # 0 = off
    ckpt_dir: str = "/tmp/repro_ckpt"
    lr_scale: float = 1.0


class TrainLoop:
    def __init__(self, cfg: ArchConfig, stream: TokenStream,
                 hyper: TrainHyper = TrainHyper(),
                 loop_cfg: LoopConfig = LoopConfig(),
                 controller: Optional[Controller] = None,
                 reshaper: Optional[MoEReshaper] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.stream = stream
        self.hyper = hyper
        self.lc = loop_cfg
        self.controller = controller or Controller()
        self.reshaper = reshaper
        self.state = make_state(cfg, jax.random.PRNGKey(seed))
        self.grad_mb, self.apply, self.migrate = build_grad_step(cfg, hyper)
        nl = lm.n_moe_layers(cfg)
        if nl:
            plan = moe_lib.identity_plan(cfg, nl)
            self.plan_slots = np.asarray(plan.slots)
            self.plan_cum = np.asarray(plan.cum)
            if reshaper is not None:
                self.plan_slots = reshaper.plan_slots.copy()
                self.plan_cum = reshaper.plan_cum.copy()
        else:
            self.plan_slots = self.plan_cum = None
        self.local_bps: List[LocalBreakpoint] = []
        self.global_bps: List[GlobalCountBreakpoint] = []
        self.history: List[Dict[str, Any]] = []
        self.ckpt = Checkpointer(self.lc.ckpt_dir) if self.lc.ckpt_every \
            else None
        if self.ckpt is not None and self.controller.durable_log_path is None:
            import os
            self.controller.attach_durable_log(
                os.path.join(self.lc.ckpt_dir, "control.log"))
        self.hit_breakpoints: List[str] = []

    # ------------------------------------------------------------- plumbing
    def _inspect(self, what: str):
        step = int(self.state["step"])
        info = {"step": step, "stream": self.stream.state(),
                "paused": self.controller.paused,
                "history_tail": self.history[-3:]}
        if what == "plan" and self.plan_slots is not None:
            info["plan_slots"] = self.plan_slots.tolist()
        return info

    def _apply_updates(self, updates: Dict[str, Any]) -> None:
        if "lr_scale" in updates:
            self.lc.lr_scale = float(updates["lr_scale"])
        if "tau" in updates and self.reshaper is not None:
            self.reshaper.params.tau = float(updates["tau"])

    def _poll(self, step: int, mb: int) -> bool:
        r = self.controller.poll(step, mb, self._inspect)
        self._apply_updates(r["updates"])
        if r["plan"] is not None:
            self.plan_slots = np.asarray(r["plan"]["slots"])
            self.plan_cum = np.asarray(r["plan"]["cum"])
            if r["plan"]["migrations"]:
                self._migrate(r["plan"]["migrations"])
        for bp in self.controller.breakpoints:
            if isinstance(bp, LocalBreakpoint):
                self.local_bps.append(bp)
            elif isinstance(bp, GlobalCountBreakpoint):
                self.global_bps.append(bp)
        self.controller.breakpoints = []
        return r["stopped"]

    def _migrate(self, migrations) -> None:
        if not migrations:
            return
        arr = jnp.asarray([[m.layer, m.src_slot, m.dst_slot]
                           for m in migrations], jnp.int32)
        self.state = self.migrate(self.state, arr)

    def _plan_args(self):
        if self.plan_slots is None:
            e = jnp.zeros((1, 1, 1), jnp.int32)
            return e, jnp.ones((1, 1, 1), jnp.float32)
        return jnp.asarray(self.plan_slots), jnp.asarray(self.plan_cum)

    # ----------------------------------------------------------------- run
    def run(self, steps: int) -> List[Dict[str, Any]]:
        n_mb = self.lc.microbatches
        for _ in range(steps):
            step = int(self.state["step"])
            if self._poll(step, 0):
                break
            batch = self.stream.next()
            gb = batch["tokens"].shape[0]
            mb_sz = gb // n_mb
            grads = None
            step_metrics: Dict[str, Any] = {}
            paused_mid = False
            for i in range(n_mb):
                mbd = {"tokens": jnp.asarray(
                    batch["tokens"][i * mb_sz:(i + 1) * mb_sz])}
                if self.cfg.enc_layers:
                    mbd["frames"] = jnp.zeros(
                        (mb_sz, self.cfg.enc_seq, self.cfg.d_model),
                        jnp.float32)
                ps, pc = self._plan_args()
                offset = (step * n_mb + i) * mb_sz * self.stream.seq_len
                g, metrics = self.grad_mb(self.state["params"], mbd, ps, pc,
                                          jnp.asarray(offset))
                grads = g if grads is None else jax.tree.map(
                    lambda a, b: a + b, grads, g)
                m_host = {k: np.asarray(v) for k, v in metrics.items()}
                step_metrics = _merge_metrics(step_metrics, m_host)
                # --- Amber granulated control point (one per microbatch) ---
                for bp in self.local_bps:
                    if bp.check({k: v for k, v in m_host.items()
                                 if np.ndim(v) == 0}):
                        self.hit_breakpoints.append(bp.name)
                        self.controller.paused = True
                for bp in list(self.global_bps):
                    if bp.update([float(mbd["tokens"].size)]):
                        self.hit_breakpoints.append(bp.name)
                        self.controller.paused = True
                        # COUNT targets fire once (unlike local condition
                        # breakpoints, which re-check every iteration)
                        self.global_bps.remove(bp)
                if self._poll(step, i + 1):
                    paused_mid = True
                    break
            if paused_mid and self.controller.stopped:
                break
            self.state, opt_m = self.apply(self.state, grads, n_mb,
                                           jnp.asarray(self.lc.lr_scale))
            step_metrics.update({k: np.asarray(v) for k, v in opt_m.items()})
            self.history.append({"step": step, **{
                k: (float(v) if np.ndim(v) == 0 else v)
                for k, v in step_metrics.items()}})
            # ---------------- Reshape between-steps fast control path ------
            if self.reshaper is not None and "expert_counts" in step_metrics:
                self.reshaper.observe(step_metrics["expert_counts"],
                                      step_metrics.get("dropped"))
                ps, pc, migs = self.reshaper.step()
                if migs:
                    self._migrate(migs)
                self.plan_slots, self.plan_cum = ps, pc
            if self.ckpt and (step + 1) % self.lc.ckpt_every == 0:
                self.save(step + 1)
        return self.history

    # -------------------------------------------------------- fault tolerance
    def save(self, step: int) -> str:
        extra = {"stream": self.stream.state(),
                 "plan_slots": None if self.plan_slots is None
                 else np.asarray(self.plan_slots),
                 "plan_cum": None if self.plan_cum is None
                 else np.asarray(self.plan_cum),
                 "lr_scale": self.lc.lr_scale}
        return self.ckpt.save(step, self.state, self.controller.log, extra)

    @classmethod
    def recover(cls, cfg: ArchConfig, stream: TokenStream,
                hyper: TrainHyper, loop_cfg: LoopConfig,
                reshaper: Optional[MoEReshaper] = None) -> "TrainLoop":
        import os
        ckpt = Checkpointer(loop_cfg.ckpt_dir)
        payload = ckpt.restore()
        assert payload is not None, "no checkpoint to recover from"
        step = payload["step"]
        # the coordinator's durable log survives the crash (§2.6.2 A1) and
        # includes messages applied after the checkpoint was taken
        durable = Controller.read_durable_log(
            os.path.join(loop_cfg.ckpt_dir, "control.log"))
        records = durable or payload["control_log"]
        controller = ReplayingController(
            [r for r in records if r.step >= step])
        loop = cls(cfg, stream, hyper, loop_cfg, controller=controller,
                   reshaper=reshaper)
        loop.state = jax.tree.map(jnp.asarray, payload["state"])
        loop.stream.restore(payload["extra"]["stream"])
        loop.lc.lr_scale = payload["extra"]["lr_scale"]
        if payload["extra"]["plan_slots"] is not None:
            loop.plan_slots = payload["extra"]["plan_slots"]
            loop.plan_cum = payload["extra"]["plan_cum"]
        # replayed messages were already logged pre-crash; keep the old log
        loop.controller.log = list(records)
        return loop


def _merge_metrics(acc: Dict[str, Any], new: Dict[str, Any]) -> Dict[str, Any]:
    out = dict(acc)
    for k, v in new.items():
        if k not in out:
            out[k] = v
        elif np.ndim(v) == 0:
            out[k] = (out[k] + v) / 2 if k in ("ce", "loss", "aux_loss") \
                else out[k] + v
        else:
            out[k] = out[k] + v
    return out
