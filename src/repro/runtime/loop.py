"""Interactive training loop — an engine client on the ML runtime.

The loop no longer owns the control plane: an :class:`repro.engine.Engine`
holds the controller mailbox, the durable control-replay log, and the
registered breakpoints, and the loop submits its work as engine *jobs*
(train step on either path, checkpoint).  Which step path runs is the
engine's Maestro decision (``choose_step_path``): granulated whenever
interactivity is live — the Amber per-microbatch control points (§2.4.3/4)
— otherwise the cheaper path under the measured cost model (which subsumes
the old hard-coded ``auto`` heuristic).  Reshape (MoEReshaper) observes the
free load metrics and swaps the routing plan + migrates expert state
between steps.  Fault tolerance: checkpoints carry the data-iterator state
and the control-replay log; ``TrainLoop.recover`` restores and re-applies
logged messages at their recorded (step, microbatch) points -> bit-exact
continuation (§2.6.2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ArchConfig
from repro.core.breakpoints import GlobalCountBreakpoint, LocalBreakpoint
from repro.core.controller import Controller, ReplayingController
from repro.core.reshape_moe import MoEReshaper
from repro.data.synthetic import TokenStream
from repro.engine.engine import Engine
from repro.engine.jobs import Job, dispatch_kind
from repro.models import lm
from repro.models import moe as moe_lib
from repro.runtime.train import (TrainHyper, build_fused_step,
                                 build_grad_step, make_state)


@dataclasses.dataclass
class LoopConfig:
    microbatches: int = 2
    ckpt_every: int = 0                  # 0 = off
    ckpt_dir: str = "/tmp/repro_ckpt"
    # two-region checkpointing: the blocking device->host snapshot always
    # runs between steps; with ckpt_async the host->disk persist runs on the
    # Checkpointer worker thread, overlapped with the next step's regions
    # (False = legacy blocking save, the measured baseline)
    ckpt_async: bool = True
    # publish host-side params to `publish_to` every N steps (0 = off):
    # the train->serve weight-publishing hook (ROADMAP item 3)
    publish_every: int = 0
    lr_scale: float = 1.0
    # step-path selection: "auto" pays the granulated interactivity tax only
    # when interactivity is in use (pending message / breakpoint / pause /
    # replay); "granulated" and "fused" force one path (benchmarks).
    step_path: str = "auto"
    # MoE dispatch kernel selection: "off" keeps the cfg's fused_dispatch
    # setting; "auto" lets the engine pick fused-vs-XLA per shape from
    # measured CostBook step times; "fused"/"xla" force one impl.  Only
    # meaningful for MoE configs.
    dispatch_select: str = "off"


class TrainLoop:
    def __init__(self, cfg: ArchConfig, stream: TokenStream,
                 hyper: TrainHyper = TrainHyper(),
                 loop_cfg: LoopConfig = LoopConfig(),
                 controller: Optional[Controller] = None,
                 reshaper: Optional[MoEReshaper] = None,
                 seed: int = 0, engine: Optional[Engine] = None,
                 publish_to: Any = None):
        self.cfg = cfg
        self.stream = stream
        self.hyper = hyper
        self.lc = loop_cfg
        assert loop_cfg.step_path in ("auto", "fused", "granulated"), \
            loop_cfg.step_path
        assert loop_cfg.dispatch_select in ("off", "auto", "fused", "xla"), \
            loop_cfg.dispatch_select
        assert engine is None or controller is None, \
            "pass either an engine or a bare controller, not both"
        self.engine = engine or Engine(controller=controller)
        self.reshaper = reshaper
        self.state = make_state(cfg, jax.random.PRNGKey(seed))
        self.grad_mb, self.apply, self.migrate = build_grad_step(cfg, hyper)
        self.fused_step = build_fused_step(cfg, hyper)
        # per-dispatch-impl step fns, built lazily when the engine is
        # selecting the MoE dispatch kernel at runtime (dispatch_select);
        # _impl_warm tracks which (impl, path) jits have already run once,
        # so their compile-carrying first step is marked cold and never
        # enters ANY cost EMA (a fresh impl jit would otherwise poison the
        # train_step_* estimates and flip the step-path decision)
        self._impl_fns: Dict[str, Any] = {}
        self._impl_warm: set = set()
        self._plan_dev = None            # cached device-resident plan arrays
        nl = lm.n_moe_layers(cfg)
        if nl:
            plan = moe_lib.identity_plan(cfg, nl)
            self._set_plan(np.asarray(plan.slots), np.asarray(plan.cum))
            if reshaper is not None:
                self._set_plan(reshaper.plan_slots.copy(),
                               reshaper.plan_cum.copy())
        else:
            self.plan_slots = self.plan_cum = None
        self.history: List[Dict[str, Any]] = []
        # weight-publish sink: a ServeEngine (its .update() mailbox) or a
        # bare Controller (.send); params go out as host-numpy trees
        self.publish_to = publish_to
        self._last_snapshot: Optional[Dict[str, Any]] = None
        self.ckpt = Checkpointer(self.lc.ckpt_dir) if self.lc.ckpt_every \
            else None
        if self.ckpt is not None and self.controller.durable_log_path is None:
            import os
            self.controller.attach_durable_log(
                os.path.join(self.lc.ckpt_dir, "control.log"))
        self.hit_breakpoints: List[str] = []

    # the control plane lives on the engine; these views keep the worker's
    # historical surface (tests, examples, benchmarks) intact
    @property
    def controller(self) -> Controller:
        return self.engine.controller

    @property
    def local_bps(self) -> List[LocalBreakpoint]:
        return self.engine.local_bps

    @property
    def global_bps(self) -> List[GlobalCountBreakpoint]:
        return self.engine.global_bps

    # ------------------------------------------------------------- plumbing
    def _inspect(self, what: str):
        step = int(self.state["step"])
        info = {"step": step, "stream": self.stream.state(),
                "paused": self.controller.paused,
                "history_tail": self.history[-3:]}
        if what == "plan" and self.plan_slots is not None:
            info["plan_slots"] = self.plan_slots.tolist()
        if what == "engine":
            info["engine"] = self.engine.inspect()
        return info

    def _apply_updates(self, updates: Dict[str, Any]) -> None:
        if "lr_scale" in updates:
            self.lc.lr_scale = float(updates["lr_scale"])
        if "tau" in updates and self.reshaper is not None:
            self.reshaper.params.tau = float(updates["tau"])

    def _poll(self, step: int, mb: int) -> bool:
        r = self.engine.poll(step, mb, self._inspect)
        self._apply_updates(r["updates"])
        if r["plan"] is not None:
            self._set_plan(np.asarray(r["plan"]["slots"]),
                           np.asarray(r["plan"]["cum"]))
            if r["plan"]["migrations"]:
                self._migrate(r["plan"]["migrations"])
        return r["stopped"]

    def _migrate(self, migrations) -> None:
        if not migrations:
            return
        arr = jnp.asarray([[m.layer, m.src_slot, m.dst_slot]
                           for m in migrations], jnp.int32)
        self.state = self.migrate(self.state, arr)

    def _set_plan(self, slots, cum) -> None:
        """Single mutation point for the routing plan.  The cached device
        arrays are invalidated only when the plan VALUES change — the reshaper
        returns fresh copies every step, which must not force an H2D
        re-upload per step (let alone the old one per microbatch)."""
        if (self._plan_dev is not None and self.plan_slots is not None
                and np.array_equal(slots, self.plan_slots)
                and np.array_equal(cum, self.plan_cum)):
            self.plan_slots, self.plan_cum = slots, cum
            return
        self.plan_slots, self.plan_cum = slots, cum
        self._plan_dev = None

    def _plan_args(self):
        if self._plan_dev is None:
            if self.plan_slots is None:
                self._plan_dev = (jnp.zeros((1, 1, 1), jnp.int32),
                                  jnp.ones((1, 1, 1), jnp.float32))
            else:
                self._plan_dev = (jnp.asarray(self.plan_slots),
                                  jnp.asarray(self.plan_cum))
        return self._plan_dev

    # ----------------------------------------------------------------- run
    def _dispatch_impl(self, n_tok: int):
        """Engine-chosen MoE dispatch kernel for this step (or None when
        selection is off / the model has no MoE).  Returns (impl,
        (grad_mb, fused_step)) — the step fns jitted for that impl."""
        if self.lc.dispatch_select == "off" or self.cfg.moe is None:
            return None, (self.grad_mb, self.fused_step)
        forced = ("auto" if self.lc.dispatch_select == "auto"
                  else self.lc.dispatch_select)
        impl = self.engine.choose_dispatch_impl(n_tok, forced=forced)
        if impl not in self._impl_fns:
            c = dataclasses.replace(
                self.cfg, moe=dataclasses.replace(
                    self.cfg.moe, fused_dispatch=(impl == "fused")))
            gm, _, _ = build_grad_step(c, self.hyper)
            self._impl_fns[impl] = (gm, build_fused_step(c, self.hyper))
        return impl, self._impl_fns[impl]

    def _fused_eligible(self) -> bool:
        """Step-path choice, delegated to the engine.  Whenever interactivity
        is actually in use (pending/replaying message, breakpoint, paused)
        the engine returns the granulated path so Amber's per-microbatch
        semantics are preserved exactly; otherwise it scores both step-job
        workflows under the measured cost model and picks the cheaper —
        the PR-1 ``auto`` heuristic, now as a Maestro decision."""
        return self.engine.choose_step_path(
            self.lc.step_path, self.lc.microbatches) == "fused"

    def _check_breakpoints(self, m_host: Dict[str, Any],
                           tokens_count: float) -> None:
        for bp in self.local_bps:
            if bp.check({k: v for k, v in m_host.items()
                         if np.ndim(v) == 0}):
                self.hit_breakpoints.append(bp.name)
                self.controller.paused = True
        for bp in list(self.global_bps):
            if bp.update([tokens_count]):
                self.hit_breakpoints.append(bp.name)
                self.controller.paused = True
                # COUNT targets fire once (unlike local condition
                # breakpoints, which re-check every iteration)
                self.global_bps.remove(bp)

    def _step_granulated(self, step: int, batch, n_mb: int, grad_mb=None):
        """One training step at microbatch control granularity (§2.4.3).
        Returns (step_metrics, stopped); metrics is None when stopped."""
        grad_mb = self.grad_mb if grad_mb is None else grad_mb
        gb = batch["tokens"].shape[0]
        mb_sz = gb // n_mb
        grads = None
        sums: Dict[str, Any] = {}
        mb_done = 0
        for i in range(n_mb):
            mbd = {"tokens": jnp.asarray(
                batch["tokens"][i * mb_sz:(i + 1) * mb_sz])}
            if self.cfg.enc_layers:
                mbd["frames"] = jnp.zeros(
                    (mb_sz, self.cfg.enc_seq, self.cfg.d_model),
                    jnp.float32)
            ps, pc = self._plan_args()
            offset = (step * n_mb + i) * mb_sz * self.stream.seq_len
            g, metrics = grad_mb(self.state["params"], mbd, ps, pc,
                                 jnp.asarray(offset))
            grads = g if grads is None else jax.tree.map(
                lambda a, b: a + b, grads, g)
            m_host = {k: np.asarray(v) for k, v in metrics.items()}
            sums = _merge_metrics(sums, m_host)
            mb_done += 1
            # --- Amber granulated control point (one per microbatch) ---
            self._check_breakpoints(m_host, float(mbd["tokens"].size))
            if self._poll(step, i + 1):
                return None, True
        step_metrics = _finalize_metrics(sums, mb_done)
        self.state, opt_m = self.apply(self.state, grads, n_mb,
                                       jnp.asarray(self.lc.lr_scale))
        step_metrics.update({k: np.asarray(v) for k, v in opt_m.items()})
        return step_metrics, False

    def _step_fused(self, batch, n_mb: int, fused_step=None) -> Dict[str, Any]:
        """One training step through the fused jit: all microbatches scanned
        in-device, one dispatch, one device->host metrics fetch."""
        fused_step = self.fused_step if fused_step is None else fused_step
        gb = batch["tokens"].shape[0]
        used = (gb // n_mb) * n_mb      # granulated path drops the remainder
        bd = {"tokens": jnp.asarray(batch["tokens"][:used])}
        if self.cfg.enc_layers:
            bd["frames"] = jnp.zeros(
                (used, self.cfg.enc_seq, self.cfg.d_model), jnp.float32)
        ps, pc = self._plan_args()
        self.state, mb_metrics, opt_m = fused_step(
            self.state, bd, ps, pc, jnp.asarray(self.lc.lr_scale),
            n_mb=n_mb)
        mb_host, opt_host = jax.device_get((mb_metrics, opt_m))
        if self.local_bps or self.global_bps:
            # forced step_path="fused" with registered breakpoints (auto
            # mode never gets here): evaluate the predicates post hoc on
            # the stacked per-microbatch metrics
            tokens_mb = float(used * batch["tokens"].shape[1]) / n_mb
            for i in range(n_mb):
                self._check_breakpoints(
                    {k: np.asarray(v)[i] for k, v in mb_host.items()},
                    tokens_mb)
        step_metrics = {
            k: (np.asarray(v).mean(0) if k in _MEAN_KEYS
                else np.asarray(v).sum(0))
            for k, v in mb_host.items()}
        step_metrics.update({k: np.asarray(v) for k, v in opt_host.items()})
        return step_metrics

    def run(self, steps: int) -> List[Dict[str, Any]]:
        n_mb = self.lc.microbatches
        for _ in range(steps):
            step = int(self.state["step"])
            if self._poll(step, 0):
                break
            batch = self.stream.next()
            n_tok = int(batch["tokens"].size)
            impl, (grad_mb, fused_step) = self._dispatch_impl(n_tok)
            fused_path = self._fused_eligible()
            extra, meta = (), None
            if impl is not None:
                key = (impl, fused_path)
                meta = {"cold": key not in self._impl_warm}
                self._impl_warm.add(key)
                if fused_path:
                    # dispatch-impl samples come from fused-path steps only:
                    # mixing fused- and granulated-step durations under one
                    # dispatch_kind key would compare the impls across
                    # different step paths, not against each other
                    extra = (Job(dispatch_kind(impl, n_tok), tokens=n_tok,
                                 meta=meta),)
            if fused_path:
                step_metrics = self.engine.run_job(
                    Job("train_step_fused", tokens=n_tok, meta=meta),
                    lambda: self._step_fused(batch, n_mb, fused_step),
                    extra=extra)
            else:
                t0 = time.perf_counter()
                log_before = len(self.controller.log)
                step_metrics, stopped = self._step_granulated(
                    step, batch, n_mb, grad_mb)
                if stopped:
                    break
                if len(self.controller.log) == log_before:
                    # clean measurement only: a step that served control
                    # messages (or sat paused) must not poison the cost model
                    self.engine.observe(
                        Job("train_step_granulated", tokens=n_tok,
                            meta=meta), time.perf_counter() - t0)
            self.history.append({"step": step, **{
                k: (float(v) if np.ndim(v) == 0 else v)
                for k, v in step_metrics.items()}})
            # ---------------- Reshape between-steps fast control path ------
            if self.reshaper is not None and "expert_counts" in step_metrics:
                self.reshaper.observe(step_metrics["expert_counts"],
                                      step_metrics.get("dropped"))
                ps, pc, migs = self.reshaper.step()
                if migs:
                    self._migrate(migs)
                self._set_plan(ps, pc)
            if self.ckpt and (step + 1) % self.lc.ckpt_every == 0:
                self.save(step + 1)
            if self.publish_to is not None and self.lc.publish_every and \
                    (step + 1) % self.lc.publish_every == 0:
                self.publish(step + 1)
        if self.ckpt is not None:
            # completion barrier: every queued persist is durable (and any
            # worker-side error re-raised here) before run() returns
            self.ckpt.wait()
        return self.history

    # -------------------------------------------------------- fault tolerance
    def save(self, step: int) -> str:
        """Two-region checkpoint (engine.jobs.snapshot_workflow /
        persist_workflow): the blocking device->host snapshot runs inline as
        a measured ``ckpt_snapshot`` job, then the host->disk persist either
        queues on the Checkpointer worker (ckpt_async — its measured wall
        time feeds the ``ckpt_persist`` EMA from the completion callback, so
        the scheduler prices the overlapped region from observation) or runs
        inline as the blocking baseline.  Returns the checkpoint path the
        persist will (or did) publish."""
        extra = {"stream": self.stream.state(),
                 "plan_slots": None if self.plan_slots is None
                 else np.asarray(self.plan_slots),
                 "plan_cum": None if self.plan_cum is None
                 else np.asarray(self.plan_cum),
                 "lr_scale": self.lc.lr_scale}
        payload = self.engine.run_job(
            Job("ckpt_snapshot"),
            lambda: self.ckpt.snapshot(step, self.state,
                                       self.controller.log, extra))
        self._last_snapshot = payload
        if self.lc.ckpt_async:
            self.ckpt.persist_async(
                payload, on_done=lambda dt: self.engine.observe(
                    Job("ckpt_persist"), dt))
        else:
            self.engine.run_job(Job("ckpt_persist"),
                                lambda: self.ckpt.persist(payload))
        return self.ckpt._path(step)

    def publish(self, version: int) -> None:
        """Send the current host-side params to ``publish_to`` tagged with
        ``version`` (the train step).  Reuses the checkpoint snapshot's
        host copy when one was just taken at this step — publish and persist
        then share a single device sync.  The sink applies the swap at its
        own tick boundary (``ServeEngine.update`` mailbox semantics)."""
        snap = self._last_snapshot
        if snap is not None and snap["step"] == version:
            params = snap["state"]["params"]
        else:
            params = jax.tree.map(np.asarray, self.state["params"])
        target = self.publish_to
        if hasattr(target, "update"):       # ServeEngine
            target.update(params=params, params_version=version)
        else:                               # bare Controller mailbox
            from repro.core import messages as M
            target.send(M.update(params=params, params_version=version))

    @classmethod
    def recover(cls, cfg: ArchConfig, stream: TokenStream,
                hyper: TrainHyper, loop_cfg: LoopConfig,
                reshaper: Optional[MoEReshaper] = None) -> "TrainLoop":
        import os
        ckpt = Checkpointer(loop_cfg.ckpt_dir)
        payload = ckpt.restore()
        assert payload is not None, "no checkpoint to recover from"
        step = payload["step"]
        # the coordinator's durable log survives the crash (§2.6.2 A1) and
        # includes messages applied after the checkpoint was taken
        durable = Controller.read_durable_log(
            os.path.join(loop_cfg.ckpt_dir, "control.log"))
        records = durable or payload["control_log"]
        controller = ReplayingController(
            [r for r in records if r.step >= step])
        loop = cls(cfg, stream, hyper, loop_cfg, controller=controller,
                   reshaper=reshaper)
        loop.state = jax.tree.map(jnp.asarray, payload["state"])
        loop.stream.restore(payload["extra"]["stream"])
        loop.lc.lr_scale = payload["extra"]["lr_scale"]
        if payload["extra"]["plan_slots"] is not None:
            loop._set_plan(payload["extra"]["plan_slots"],
                           payload["extra"]["plan_cum"])
        # replayed messages were already logged pre-crash; keep the old log
        loop.controller.log = list(records)
        return loop


# metric keys averaged over microbatches; everything else is summed
_MEAN_KEYS = ("ce", "loss", "aux_loss")


def _merge_metrics(acc: Dict[str, Any], new: Dict[str, Any]) -> Dict[str, Any]:
    """Accumulate per-microbatch metric SUMS (mean keys are divided once by
    the microbatch count in ``_finalize_metrics`` — a running (a+b)/2 average
    would exponentially down-weight early microbatches when n_mb > 2)."""
    out = dict(acc)
    for k, v in new.items():
        out[k] = v if k not in out else out[k] + v
    return out


def _finalize_metrics(sums: Dict[str, Any], n_mb: int) -> Dict[str, Any]:
    out = dict(sums)
    for k in _MEAN_KEYS:
        if k in out:
            out[k] = out[k] / max(n_mb, 1)
    return out
