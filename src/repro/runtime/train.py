"""Train-step builder: fwd/bwd with microbatch gradient accumulation (scan),
MoE Reshape plan as a jittable input, remat policy from the Maestro choice,
AdamW, and the load metrics (phi) as free step outputs."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import lm
from repro.models import moe as moe_lib
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    opt: adamw.AdamWCfg = adamw.AdamWCfg()
    aux_coef: float = 0.01
    z_coef: float = 1e-4
    remat: str = "none"


def make_state(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    params = lm.init(cfg, key, dtype)
    return {"params": params, "opt": adamw.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(cfg: ArchConfig, dtype=jnp.float32) -> Dict[str, Any]:
    params = lm.abstract(cfg, dtype)
    zeros = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         params)
    return {"params": params,
            "opt": adamw.OptState(zeros, jax.tree.map(lambda x: x, zeros),
                                  jax.ShapeDtypeStruct((), jnp.int32)),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def loss_fn(params, batch, cfg: ArchConfig, hyper: TrainHyper, plan,
            token_offset, mesh=None, act_spec=None, tokens_sharded=True,
            layout="tp"):
    # mixed precision: compute in bf16 (one cast up front so the FSDP
    # all-gather of the layer stacks moves bf16, not fp32 master weights —
    # halves the gathered-stack footprint the compiler hoists out of scan)
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if x.dtype == jnp.float32 else x, params)
    logits, aux = lm.forward(params, batch, cfg, plan=plan,
                             token_offset=token_offset, remat=hyper.remat,
                             mesh=mesh, act_spec=act_spec,
                             tokens_sharded=tokens_sharded, layout=layout)
    tokens = batch["tokens"]
    labels = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    loss = ce.mean()
    metrics = {"ce": loss}
    if "moe" in aux:
        aux_l = aux["moe"]["aux_loss"].mean()
        z_l = aux["moe"]["router_z"].mean()
        loss = loss + hyper.aux_coef * aux_l + hyper.z_coef * z_l
        metrics["aux_loss"] = aux_l
        metrics["expert_counts"] = aux["moe"]["expert_counts"]  # [L, E]
        metrics["slot_counts"] = aux["moe"]["slot_counts"]      # [L, S]
        metrics["dropped"] = aux["moe"]["dropped"]              # [L]
    metrics["loss"] = loss
    return loss, metrics


def build_train_step(cfg: ArchConfig, shape: ShapeCfg, hyper: TrainHyper,
                     mesh=None, act_spec=None, layout="tp"):
    """Production step: microbatches scanned inside one jit."""
    n_mb = max(1, shape.microbatches)
    nl_moe = lm.n_moe_layers(cfg)

    def step(state, batch, plan_slots, plan_cum):
        plan = moe_lib.RoutingPlan(plan_slots, plan_cum) if nl_moe else None
        tokens = batch["tokens"]
        gb, s = tokens.shape
        mb = gb // n_mb

        def reshape_mb(x):
            return x.reshape((n_mb, mb) + x.shape[1:])

        mb_batch = {k: reshape_mb(v) for k, v in batch.items()
                    if k in ("tokens", "frames", "positions3")}
        grad_zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

        def mb_body(carry, inp):
            gacc, i = carry
            mbd = inp
            offset = (state["step"].astype(jnp.int32) * n_mb + i) * (mb * s)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], mbd, cfg, hyper,
                                       plan, offset, mesh, act_spec,
                                       True, layout)
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_mb, gacc, grads)
            return (gacc, i + 1), metrics

        (grads, _), metrics = jax.lax.scan(
            mb_body, (grad_zero, jnp.zeros((), jnp.int32)), mb_batch)
        metrics = jax.tree.map(
            lambda m: m.sum(0) if m.dtype in (jnp.int32, jnp.int64)
            else m.mean(0), metrics)
        params, opt, opt_metrics = adamw.apply(
            state["params"], grads, state["opt"], hyper.opt)
        metrics.update(opt_metrics)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        return new_state, metrics

    return step


def build_fused_step(cfg: ArchConfig, hyper: TrainHyper):
    """Fused fast path for the interactive loop: ALL microbatches run inside
    one jit via ``lax.scan`` with in-jit gradient accumulation, followed by
    the optimizer apply — one dispatch and one device->host metrics fetch per
    step instead of ``2 * n_mb`` dispatches plus per-microbatch syncs.

    Numerics mirror the granulated path exactly: per-microbatch grads are
    summed in fp32 in microbatch order, divided once by ``n_mb``, and fed to
    the same ``adamw.apply``.  Metrics come back STACKED per microbatch
    ``[n_mb, ...]`` so the host can still evaluate breakpoint predicates at
    microbatch granularity post hoc.

    The old state is donated (buffer reuse for params/opt moments) on
    accelerator backends; CPU ignores donation, so skip it there to avoid
    per-step warnings.
    """
    nl_moe = lm.n_moe_layers(cfg)
    donate = (0,) if jax.default_backend() != "cpu" else ()

    @partial(jax.jit, static_argnames=("n_mb",), donate_argnums=donate)
    def fused(state, batch, plan_slots, plan_cum, lr_scale, n_mb: int):
        plan = moe_lib.RoutingPlan(plan_slots, plan_cum) if nl_moe else None
        tokens = batch["tokens"]
        gb, s = tokens.shape
        mb = gb // n_mb

        mb_batch = {k: v.reshape((n_mb, mb) + v.shape[1:])
                    for k, v in batch.items()
                    if k in ("tokens", "frames", "positions3")}
        # hoist the fp32->bf16 params cast out of the scan: XLA does not
        # move it through value_and_grad, so the per-microbatch path would
        # re-cast every iteration.  Differentiating w.r.t. the bf16 tree
        # yields exactly the cotangents the fp32 cast's VJP would upcast,
        # so accumulating their fp32 upcast is bit-identical to the
        # granulated path (loss_fn's internal cast is a no-op on bf16).
        params_bf = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if x.dtype == jnp.float32 else x, state["params"])
        grad_zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

        def mb_body(carry, mbd):
            gacc, i = carry
            offset = (state["step"].astype(jnp.int32) * n_mb + i) * (mb * s)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params_bf, mbd, cfg, hyper,
                                       plan, offset)
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (gacc, i + 1), metrics

        (grads, _), mb_metrics = jax.lax.scan(
            mb_body, (grad_zero, jnp.zeros((), jnp.int32)), mb_batch)
        grads = jax.tree.map(lambda g: g / n_mb, grads)
        params, opt, opt_m = adamw.apply(state["params"], grads,
                                         state["opt"], hyper.opt, lr_scale)
        new_state = {"params": params, "opt": opt,
                     "step": state["step"] + 1}
        return new_state, mb_metrics, opt_m

    return fused


def build_grad_step(cfg: ArchConfig, hyper: TrainHyper, donate=None):
    """Interactive-mode pieces: one-microbatch grad + separate apply (the
    Amber granulated iteration: the loop polls control between microbatches).

    ``apply`` and ``migrate`` donate the incoming state (params + opt
    moments are overwritten in place on accelerator backends) — without it
    the granulated path allocated fresh params/opt buffers every step while
    the fused path reused them.  The loop's ``self.state = apply(...)`` /
    ``self.state = migrate(...)`` call pattern never touches the old state
    afterwards, which is what makes donation safe.  CPU ignores donation
    (and warns per compile), so it defaults off there; tests force it on
    via ``donate`` to audit the wiring.
    """
    nl_moe = lm.n_moe_layers(cfg)
    if donate is None:
        donate = jax.default_backend() != "cpu"
    donate_state = (0,) if donate else ()

    @jax.jit
    def grad_mb(params, batch, plan_slots, plan_cum, offset):
        plan = moe_lib.RoutingPlan(plan_slots, plan_cum) if nl_moe else None
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, hyper, plan, offset)
        return grads, metrics

    @partial(jax.jit, static_argnames=("n_mb",), donate_argnums=donate_state)
    def apply(state, grads, n_mb: int, lr_scale):
        grads = jax.tree.map(lambda g: g / n_mb, grads)
        params, opt, m = adamw.apply(state["params"], grads, state["opt"],
                                     hyper.opt, lr_scale)
        return {"params": params, "opt": opt, "step": state["step"] + 1}, m

    @partial(jax.jit, donate_argnums=donate_state)
    def migrate(state, src_dst):
        """Expert state migration: copy slot src->dst on every expert-stacked
        leaf of params AND optimizer moments (layer, src, dst) int32 [M,3]."""
        def copy_leaf(leaf):
            if leaf.ndim >= 2:
                def one(carry, m):
                    lyr, src, dst = m[0], m[1], m[2]
                    row = jax.lax.dynamic_index_in_dim(
                        jax.lax.dynamic_index_in_dim(carry, lyr, 0, False),
                        src, 0, False)
                    carry = jax.lax.dynamic_update_index_in_dim(
                        carry, jax.lax.dynamic_update_index_in_dim(
                            jax.lax.dynamic_index_in_dim(carry, lyr, 0, False),
                            row, dst, 0), lyr, 0)
                    return carry, None
                leaf, _ = jax.lax.scan(one, leaf, src_dst)
            return leaf

        def on_moe(tree):
            return {k: (jax.tree.map(copy_leaf, v)
                        if k in ("w_gate", "w_up", "w_down") else v)
                    for k, v in tree.items()}

        params = dict(state["params"])
        opt = state["opt"]
        if "moe" in params:
            params["moe"] = on_moe(params["moe"])
            m = dict(opt.m)
            v = dict(opt.v)
            m["moe"] = on_moe(m["moe"])
            v["moe"] = on_moe(v["moe"])
            opt = adamw.OptState(m, v, opt.count)
        return {"params": params, "opt": opt, "step": state["step"]}

    return grad_mb, apply, migrate
