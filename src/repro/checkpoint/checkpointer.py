"""Checkpointing substrate: pickled host-numpy pytree snapshots (pickle
protocol 4 — self-describing and dependency-free; a msgpack+raw-numpy
container would be a format swap behind the same API) with fsynced atomic
publish, retention, an append-only ack manifest, and the Amber
control-replay log (paper §2.6.2) — recovery = restore + deterministic
replay of logged control messages.

Checkpointing is two regions (the Maestro split in
``engine.jobs.snapshot_workflow`` / ``persist_workflow``):

* **snapshot** — device→host copy of the state tree plus the control log.
  Blocking but cheap: one device sync, no I/O.  The payload it returns is
  immutable from the trainer's point of view, so the training step after it
  may freely update device state.
* **persist** — host→disk serialization, the expensive part.
  ``persist_async`` runs it on a single worker thread (persists stay
  serialized in submission order, so acks land in order), overlapped with
  the next training step; ``wait()`` is the completion barrier that
  re-raises worker errors.

Durability discipline (the durable-log barrier): the payload bytes are
fsynced *before* the atomic rename publishes them, the directory entry is
fsynced after, and only then is the step acknowledged in the append-only
``MANIFEST.log`` (each ack line itself fsynced).  ``restore`` only
considers acknowledged steps — a crash mid-``persist`` leaves at worst an
orphaned tmp file or an unacknowledged checkpoint, and recovery falls back
to the previous acknowledged step and replays the control log from there
(§2.6.2).  Recovery can therefore never see a checkpoint the log does not
acknowledge, and never a torn one.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.messages import LogRecord


def _to_numpy_tree(tree):
    # np.array (not asarray): device leaves copy to host either way, but a
    # leaf that is ALREADY host numpy must copy too — the snapshot payload
    # is the persist worker's to read while the next step mutates live state
    return jax.tree.map(lambda x: np.array(x), tree)


class Checkpointer:
    MANIFEST = "MANIFEST.log"

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._worker = None            # lazy single persist thread
        self._pending: List[Any] = []  # outstanding persist futures
        self._lock = threading.Lock()

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.pkl")

    def _manifest(self) -> str:
        return os.path.join(self.dir, self.MANIFEST)

    def _fsync_dir(self) -> None:
        fd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    # ------------------------------------------------------- snapshot region
    def snapshot(self, step: int, state: Any,
                 control_log: Optional[List[LogRecord]] = None,
                 extra: Optional[Dict] = None) -> Dict:
        """Blocking device→host capture: one device sync, no I/O.  The
        returned payload is decoupled from device state — the next train
        step may mutate params/opt state while this payload persists."""
        return {
            "step": int(step),
            "state": _to_numpy_tree(state),
            "control_log": [dataclasses.asdict(r) for r in control_log or []],
            "extra": extra or {},
        }

    # -------------------------------------------------------- persist region
    def persist(self, payload: Dict) -> str:
        """Host→disk: serialize, fsync the bytes, publish atomically, fsync
        the directory entry, THEN acknowledge the step in the manifest.
        Every state transition a crash can interrupt leaves ``restore`` a
        consistent previous step to fall back to."""
        step = payload["step"]
        path = self._path(step)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(payload, f, protocol=4)
            f.flush()
            os.fsync(f.fileno())       # bytes durable BEFORE the rename
        os.replace(tmp, path)          # atomic publish
        self._fsync_dir()              # ...and the rename itself
        self._ack(step)                # durable-log barrier: now restorable
        self._gc()
        return path

    def persist_async(self, payload: Dict, on_done=None):
        """Queue ``persist`` on the worker thread and return its future.
        ``on_done(seconds)`` (optional) receives the measured persist wall
        time — the engine feeds it into the ``ckpt_persist`` cost EMA so
        the scheduler prices the overlapped region from measurement."""
        import time as _time

        def work():
            t0 = _time.perf_counter()
            path = self.persist(payload)
            if on_done is not None:
                on_done(_time.perf_counter() - t0)
            return path

        if self._worker is None:
            from concurrent.futures import ThreadPoolExecutor
            self._worker = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ckpt-persist")
        fut = self._worker.submit(work)
        with self._lock:
            self._pending.append(fut)
        return fut

    def wait(self) -> None:
        """Barrier: block until every outstanding persist has landed (and
        re-raise any worker-side error here, on the caller's thread)."""
        with self._lock:
            pending, self._pending = self._pending, []
        for fut in pending:
            fut.result()

    def save(self, step: int, state: Any,
             control_log: Optional[List[LogRecord]] = None,
             extra: Optional[Dict] = None) -> str:
        """Blocking save: snapshot + persist in one call (the legacy API
        and the async path's measured baseline)."""
        return self.persist(self.snapshot(step, state, control_log, extra))

    # ---------------------------------------------------------- ack manifest
    def _ack(self, step: int) -> None:
        with open(self._manifest(), "a") as f:
            f.write(json.dumps({"step": int(step)}) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def acked_steps(self) -> Optional[set]:
        """Acknowledged steps, or None when no manifest exists (a legacy
        directory: every published file is trusted, pre-barrier behavior)."""
        path = self._manifest()
        if not os.path.exists(path):
            return None
        out = set()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.add(int(json.loads(line)["step"]))
                except (ValueError, KeyError):
                    continue           # torn trailing ack line: not acked
        return out

    # ------------------------------------------------------------- retention
    def _gc(self):
        ckpts = sorted(self.list_steps())
        for s in ckpts[: -self.keep]:
            os.remove(self._path(s))

    def list_steps(self) -> List[int]:
        """Published checkpoint steps (acknowledged or not).  The step is
        the full stem between ``ckpt_`` and ``.pkl`` — filenames are
        zero-padded to 8 digits but steps >= 10**8 legitimately run longer,
        so a fixed slice would silently mis-parse them."""
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".pkl"):
                stem = f[len("ckpt_"):-len(".pkl")]
                if stem.isdigit():
                    out.append(int(stem))
        return sorted(out)

    def restorable_steps(self) -> List[int]:
        """Published AND acknowledged steps — the restore candidates."""
        steps = self.list_steps()
        acked = self.acked_steps()
        if acked is None:
            return steps
        return [s for s in steps if s in acked]

    def latest_step(self) -> Optional[int]:
        steps = self.restorable_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None):
        """Load a checkpoint payload.  With no explicit ``step``, candidates
        are tried newest-acknowledged first and a payload that fails to
        deserialize (torn by byte-level corruption despite the fsync
        discipline) falls back to the next older one — recovery always gets
        the newest checkpoint that is both acknowledged and readable."""
        if step is not None:
            return self._load(self._path(step))
        for s in reversed(self.restorable_steps()):
            try:
                return self._load(self._path(s))
            except (OSError, pickle.UnpicklingError, EOFError, ValueError,
                    KeyError):
                continue
        return None

    def _load(self, path: str):
        with open(path, "rb") as f:
            payload = pickle.load(f)
        payload["control_log"] = [LogRecord(**r)
                                  for r in payload["control_log"]]
        return payload
