"""Checkpointing substrate: msgpack+raw-numpy pytree snapshots with atomic
rename, retention, and the Amber control-replay log (paper §2.6.2) —
recovery = restore + deterministic replay of logged control messages."""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.messages import LogRecord


def _to_numpy_tree(tree):
    return jax.tree.map(lambda x: np.asarray(x), tree)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.pkl")

    def save(self, step: int, state: Any,
             control_log: Optional[List[LogRecord]] = None,
             extra: Optional[Dict] = None) -> str:
        payload = {
            "step": step,
            "state": _to_numpy_tree(state),
            "control_log": [dataclasses.asdict(r) for r in control_log or []],
            "extra": extra or {},
        }
        path = self._path(step)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(payload, f, protocol=4)
        os.replace(tmp, path)              # atomic publish
        self._gc()
        return path

    def _gc(self):
        ckpts = sorted(self.list_steps())
        for s in ckpts[: -self.keep]:
            os.remove(self._path(s))

    def list_steps(self) -> List[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".pkl"):
                out.append(int(f[5:13]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        with open(self._path(step), "rb") as f:
            payload = pickle.load(f)
        payload["control_log"] = [LogRecord(**r)
                                  for r in payload["control_log"]]
        return payload
