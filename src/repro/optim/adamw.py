"""AdamW with global-norm clipping and cosine schedule — self-contained
(no optax).  Optimizer state shards like the params (which are already fully
sharded under the 2-D model x data rules => ZeRO-style)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def init(params) -> OptState:
    z = jax.tree.map(jnp.zeros_like, params)
    return OptState(z, jax.tree.map(jnp.zeros_like, params),
                    jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWCfg, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply(params, grads, state: OptState, cfg: AdamWCfg,
          lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    count = state.count + 1
    lr = schedule(cfg, state.count) * lr_scale
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), {
        "grad_norm": gnorm, "lr": lr}
