"""Gradient compression (beyond-paper distributed-optimization trick).

int8 block-quantized data-parallel gradient all-reduce with error feedback:
grads are quantized per-leaf (scale = max|g|/127), summed across the data/pod
axes with an explicit ``shard_map`` psum on the int-encoded values (8x fewer
bytes on the wire than fp32; 4x vs bf16), then dequantized; the quantization
residual is carried to the next step (error feedback keeps convergence).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g, scale=None):
    a = jnp.max(jnp.abs(g)) if scale is None else scale
    a = jnp.maximum(a, 1e-12)
    q = jnp.clip(jnp.round(g / a * 127.0), -127, 127).astype(jnp.int8)
    return q, a


def dequantize(q, a, n_shards: float = 1.0):
    return q.astype(jnp.float32) * (a / 127.0)


def compress_tree(grads, residual):
    """Returns (quantized tree, scales tree, new residual tree)."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, a = quantize(g)
        back = dequantize(q, a)
        return q, a, g - back
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, scales, res = zip(*[one(g, r) for g, r in zip(flat_g, flat_r)])
    return (jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, scales),
            jax.tree.unflatten(tdef, res))


def quantized_psum(grads, residual, axis_names: Tuple[str, ...]):
    """Inside shard_map: error-feedback int8 all-reduce over ``axis_names``.
    int8 payloads are summed in int32 (no overflow for <=2^23 shards)."""
    # scale consensus: pmax of local scales so all shards share an encoding
    scales = jax.tree.map(
        lambda g, r: jax.lax.pmax(
            jnp.max(jnp.abs(g.astype(jnp.float32) + r)), axis_names),
        grads, residual)

    def enc(g, r, a):
        g = g.astype(jnp.float32) + r
        qq = jnp.clip(jnp.round(g / jnp.maximum(a, 1e-12) * 127.0),
                      -127, 127).astype(jnp.int8)
        back = qq.astype(jnp.float32) * (a / 127.0)
        return qq, g - back
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    flat_a = jax.tree.leaves(scales)
    qs, res = zip(*[enc(g, r, a) for g, r, a in
                    zip(flat_g, flat_r, flat_a)])
    summed = jax.tree.map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_names),
        jax.tree.unflatten(tdef, qs))
    n = 1
    out = jax.tree.map(
        lambda s, a: s.astype(jnp.float32) * (a / 127.0),
        summed, scales)
    return out, jax.tree.unflatten(tdef, res)
