"""Materialization-choice enumeration (paper §4.5.1, Figs 4.11/4.12).

A conflict exists when a blocking input edge (u -> v) and some pipelined
input path into v live in the same region (the build side cannot complete
before the probe side starts).  For each conflict, the candidate cut points
are the pipelined edges on the probe-side paths *after* the last operator
shared with the build side's ancestry (the divergence point — Fig 4.12).
A materialization choice picks one cut per conflict such that the resulting
region graph is acyclic; the result set is de-duplicated and minimal.
"""
from __future__ import annotations

import itertools
from typing import Dict, FrozenSet, List, Set, Tuple

import networkx as nx

from repro.core.regions import Workflow, is_schedulable, regions, region_of

Edge = Tuple[str, str]


def conflicts(wf: Workflow) -> List[Tuple[Edge, List[List[Edge]]]]:
    """[(blocking_edge, probe paths (edge lists) that conflict with it)]."""
    regs = regions(wf)
    out = []
    for u, v, d in wf.g.edges(data=True):
        if not d["blocking"] or d["materialized"]:
            continue
        if region_of(regs, u) is not region_of(regs, v):
            continue                        # already separated
        build_anc = nx.ancestors(wf.g, u) | {u}
        paths: List[List[Edge]] = []
        for src in wf.sources():
            for p in nx.all_simple_paths(wf.g, src, v):
                edges = list(zip(p, p[1:]))
                if edges[-1] == (u, v):
                    continue                # that's the build path itself
                if wf.g[edges[-1][0]][edges[-1][1]]["blocking"]:
                    continue                # enters v via another blocking port
                if not (set(p) & build_anc):
                    continue                # no shared ancestry, no conflict
                # cut candidates: edges after the LAST node shared with the
                # build ancestry
                last_shared = max(i for i, n in enumerate(p)
                                  if n in build_anc)
                paths.append(edges[last_shared:])
        if paths:
            out.append(((u, v), paths))
    return out


def candidate_cuts(wf: Workflow, probe_paths: List[List[Edge]]) -> List[Edge]:
    """Single pipelined edges that cut ALL conflicting probe paths."""
    sets = [set(p) for p in probe_paths]
    common = set.intersection(*sets) if sets else set()
    return [e for e in common
            if not wf.g[e[0]][e[1]]["blocking"]
            and not wf.g[e[0]][e[1]]["materialized"]]


def enumerate_choices(wf: Workflow, max_extra: int = 2) -> List[FrozenSet[Edge]]:
    """All minimal materialization choices making the workflow schedulable."""
    if is_schedulable(wf):
        return [frozenset()]
    confs = conflicts(wf)
    per_conflict = [candidate_cuts(wf, paths) for _, paths in confs]
    choices: Set[FrozenSet[Edge]] = set()
    if all(per_conflict):
        for combo in itertools.product(*per_conflict):
            c = frozenset(combo)
            if is_schedulable(wf.materialize(c)):
                choices.add(c)
    if not choices:
        # fall back: small subsets of pipelined edges
        edges = wf.pipelined_edges()
        for k in range(1, max_extra + 1):
            for combo in itertools.combinations(edges, k):
                c = frozenset(combo)
                if is_schedulable(wf.materialize(c)):
                    choices.add(c)
            if choices:
                break
    # minimality: drop choices that strictly contain another valid choice
    minimal = [c for c in choices
               if not any(o < c for o in choices)]
    return sorted(minimal, key=lambda c: (len(c), sorted(c)))
