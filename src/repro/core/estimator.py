"""Workload estimation (paper §3.3.2, §3.4.3.2).

The mean-model estimator predicts a worker's future per-interval workload as
the mean of its sampled history; its standard error of prediction is
    eps = d * sqrt(1 + 1/n)
(d = sample standard deviation, n = sample size) — the quantity Algorithm 1
steers into the user's [eps_l, eps_u] band by adjusting tau.
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Dict, List, Tuple


class MeanModelEstimator:
    def __init__(self):
        self._samples: Dict[int, List[float]] = defaultdict(list)

    def add(self, workloads: Dict[int, float]) -> None:
        for w, v in workloads.items():
            self._samples[w].append(float(v))

    def reset(self, worker: int | None = None) -> None:
        if worker is None:
            self._samples.clear()
        else:
            self._samples.pop(worker, None)

    def n(self, worker: int) -> int:
        return len(self._samples[worker])

    def predict(self, worker: int) -> Tuple[float, float]:
        """Returns (phi_hat, eps) — predicted workload and standard error."""
        xs = self._samples[worker]
        if not xs:
            return 0.0, float("inf")
        n = len(xs)
        mean = sum(xs) / n
        if n < 2:
            return mean, float("inf")
        var = sum((x - mean) ** 2 for x in xs) / (n - 1)
        eps = math.sqrt(var) * math.sqrt(1.0 + 1.0 / n)
        return mean, eps

    def predict_pair(self, s: int, h: int) -> Tuple[float, float, float]:
        """(phi_hat_S, phi_hat_H, eps) — eps pooled over the pair."""
        ps, es = self.predict(s)
        ph, eh = self.predict(h)
        eps = max(es, eh)
        return ps, ph, eps


class EMAEstimator:
    """Streaming variant used by the MoE runtime (per-slot EMAs)."""

    def __init__(self, beta: float = 0.8):
        self.beta = beta
        self.value = None
        self._var = None

    def add(self, x):
        import numpy as np
        x = np.asarray(x, dtype=float)
        if self.value is None:
            self.value = x
            self._var = x * 0.0
        else:
            delta = x - self.value
            self.value = self.beta * self.value + (1 - self.beta) * x
            self._var = self.beta * self._var + (1 - self.beta) * delta ** 2

    def predict(self):
        import numpy as np
        if self.value is None:
            return None, float("inf")
        eps = float(np.sqrt(np.mean(self._var)))
        return self.value, eps
