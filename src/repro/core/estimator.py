"""Workload estimation (paper §3.3.2, §3.4.3.2).

The mean-model estimator predicts a worker's future per-interval workload as
the mean of its sampled history; its standard error of prediction is
    eps = d * sqrt(1 + 1/n)
(d = sample standard deviation, n = sample size) — the quantity Algorithm 1
steers into the user's [eps_l, eps_u] band by adjusting tau.
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Dict, List, Tuple


class MeanModelEstimator:
    def __init__(self):
        self._samples: Dict[int, List[float]] = defaultdict(list)

    def add(self, workloads: Dict[int, float]) -> None:
        for w, v in workloads.items():
            self._samples[w].append(float(v))

    def reset(self, worker: int | None = None) -> None:
        if worker is None:
            self._samples.clear()
        else:
            self._samples.pop(worker, None)

    def n(self, worker: int) -> int:
        return len(self._samples[worker])

    def predict(self, worker: int) -> Tuple[float, float]:
        """Returns (phi_hat, eps) — predicted workload and standard error."""
        xs = self._samples[worker]
        if not xs:
            return 0.0, float("inf")
        n = len(xs)
        mean = sum(xs) / n
        if n < 2:
            return mean, float("inf")
        var = sum((x - mean) ** 2 for x in xs) / (n - 1)
        eps = math.sqrt(var) * math.sqrt(1.0 + 1.0 / n)
        return mean, eps

    def predict_pair(self, s: int, h: int) -> Tuple[float, float, float]:
        """(phi_hat_S, phi_hat_H, eps) — eps pooled over the pair."""
        ps, es = self.predict(s)
        ph, eh = self.predict(h)
        eps = max(es, eh)
        return ps, ph, eps


class CostBook:
    """Online per-job-kind cost estimates for the engine layer.

    The engine measures every job it runs (train step on either path, serve
    prefill/decode ticks, checkpoints) and feeds the wall time back here; the
    Maestro decision code reads the estimates out as region ``cost_per_tuple``
    values, so scheduling choices track the machine actually being run on
    instead of a static model.  Backed by per-kind ``EMAEstimator``s — the
    same mean/eps estimator family as the Reshape workload model (§3.3.2),
    applied to job runtimes."""

    def __init__(self, beta: float = 0.6):
        self._beta = beta
        self._est: Dict[str, "EMAEstimator"] = {}

    def observe(self, kind: str, seconds: float) -> None:
        if kind not in self._est:
            self._est[kind] = EMAEstimator(self._beta)
        self._est[kind].add(seconds)

    def observe_rate(self, kind: str, frac: float) -> None:
        """Rates — e.g. a slot pool's speculative-decode acceptance fraction
        — live next to the runtime EMAs under the same estimator family, but
        are clamped to [0, 1] on the way in: a single mis-counted tick must
        not push an estimate outside the quantity's domain, where the
        decision code (expected commits = ``1 + a·(k-1)``) would extrapolate
        nonsense."""
        self.observe(kind, min(max(float(frac), 0.0), 1.0))

    def estimate(self, kind: str, default: float | None = None):
        """EMA of measured runtimes for ``kind``; ``default`` when unmeasured
        (the engine's bootstrap: decide with priors until jobs have run)."""
        est = self._est.get(kind)
        if est is None or est.value is None:
            return default
        return float(est.value)

    def estimate_first(self, kinds, default: float | None = None):
        """First measured estimate along a fallback chain of kinds.

        The multi-pool serving engine keys tick runtimes per pool
        (``serve_decode:p<id>_per_tok``) *and* globally (``serve_decode_per_tok``):
        a pool that has run ticks is scored on its own measured speed — the
        per-pool EMA is the scheduler's parallelism term, since a pool on
        faster or more-parallel hardware simply shows a lower per-token time
        — while a pool that has not run yet borrows the fleet-wide estimate
        instead of a static prior."""
        for kind in kinds:
            v = self.estimate(kind)
            if v is not None:
                return v
        return default

    def n_kinds(self) -> int:
        return len(self._est)

    def snapshot(self) -> Dict[str, float]:
        """JSON-safe view for Inspect replies / perf artifacts."""
        return {k: float(e.value) for k, e in self._est.items()
                if e.value is not None}


class EMAEstimator:
    """Streaming variant used by the MoE runtime (per-slot EMAs)."""

    def __init__(self, beta: float = 0.8):
        self.beta = beta
        self.value = None
        self._var = None

    def add(self, x):
        import numpy as np
        x = np.asarray(x, dtype=float)
        if self.value is None:
            self.value = x
            self._var = x * 0.0
        else:
            delta = x - self.value
            self.value = self.beta * self.value + (1 - self.beta) * x
            self._var = self.beta * self._var + (1 - self.beta) * delta ** 2

    def predict(self):
        import numpy as np
        if self.value is None:
            return None, float("inf")
        eps = float(np.sqrt(np.mean(self._var)))
        return self.value, eps
