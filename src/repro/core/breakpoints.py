"""Conditional breakpoints (paper §2.5).

*Local* predicates are checkable per worker/shard independently (e.g. NaN
loss, grad-norm spike, per-shard token count).  *Global* predicates (COUNT /
SUM over all workers) use the target-splitting protocol of §2.5.3: the
principal divides the target equally, workers pause on reaching their share
and notify; the principal waits a sync timeout tau, inquires laggards,
re-divides the remainder, and repeats — trading sync time against
parallelism (Fig 2.13).

``GlobalTargetProtocol`` simulates the protocol over workers with arbitrary
production rates (continuous time) — the Fig 2.13 benchmark.  The runtime
adapter for SPMD training is in ``repro.runtime.loop`` (data shards advance
in lockstep, so the principal's view is exact per step; the protocol governs
the asynchronous data-pipeline workers).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class LocalBreakpoint:
    name: str
    predicate: Callable[[dict], bool]

    def check(self, metrics: dict) -> bool:
        return bool(self.predicate(metrics))


def nan_breakpoint() -> LocalBreakpoint:
    return LocalBreakpoint(
        "nan", lambda m: not math.isfinite(float(m.get("loss", 0.0))))


def grad_norm_breakpoint(threshold: float) -> LocalBreakpoint:
    return LocalBreakpoint(
        "grad_norm", lambda m: float(m.get("grad_norm", 0.0)) > threshold)


@dataclasses.dataclass
class GlobalCountBreakpoint:
    """Pause when the total count of X produced across shards reaches N."""
    name: str
    metric: str
    target: float
    _total: float = 0.0

    def update(self, shard_values: Sequence[float]) -> bool:
        self._total += float(sum(shard_values))
        return self._total >= self.target


# ----------------------------------------------------- §2.5.3 protocol sim

@dataclasses.dataclass
class ProtocolResult:
    total_time: float
    normal_time: float
    sync_time: float
    produced: float
    overshoot: float
    rounds: int


def run_global_target_protocol(
        target: float, rates: Sequence[float], tau: float,
        values_per_tuple: Optional[Sequence[float]] = None,
        single_worker_threshold: float = 0.0) -> ProtocolResult:
    """Continuous-time simulation of the COUNT/SUM target-splitting protocol.

    ``rates``: tuples/sec per worker.  For SUM predicates pass
    ``values_per_tuple`` (mean value each worker's tuples contribute) and a
    ``single_worker_threshold``: once the remaining target drops below it,
    the principal gives the whole remainder to ONE worker to minimize
    overshoot (paper's G2 strategy).
    """
    k = len(rates)
    vals = list(values_per_tuple or [1.0] * k)
    remaining = float(target)
    produced = 0.0
    normal_time = sync_time = 0.0
    rounds = 0
    while remaining > 1e-9:
        rounds += 1
        if remaining <= single_worker_threshold and k > 1:
            # end-game: single worker finishes the remainder
            w = max(range(k), key=lambda i: rates[i])
            n_tuples = math.ceil(remaining / vals[w])
            dt = n_tuples / rates[w]
            normal_time += dt
            got = n_tuples * vals[w]
            produced += got
            remaining -= got
            continue
        share = remaining / k
        # tuples each worker must produce to cover its share
        need = [math.ceil(share / vals[i]) for i in range(k)]
        t_first = min(need[i] / rates[i] for i in range(k))
        normal_time += t_first
        # principal waits tau; everyone keeps producing during the wait
        t_window = t_first + tau
        got_tuples = [min(need[i], math.floor(rates[i] * t_window))
                      for i in range(k)]
        # laggards are inquired and pause; add their tally
        round_produced = sum(got_tuples[i] * vals[i] for i in range(k))
        finished_in_tau = all(got_tuples[i] >= need[i] for i in range(k))
        sync_time += tau if not finished_in_tau else min(
            tau, max((need[i] / rates[i] for i in range(k))) - t_first)
        produced += round_produced
        remaining -= round_produced
    overshoot = max(0.0, produced - target)
    return ProtocolResult(normal_time + sync_time, normal_time, sync_time,
                          produced, overshoot, rounds)
