"""Reshape skew detection (paper §3.2).

Skew test between workers L (loaded) and C (candidate helper):
    phi_L >= eta            (3.1)  — L is computationally burdened
    phi_L - phi_C >= tau    (3.2)  — the gap is big enough to act on
Helper selection: the lowest-workload candidate not already assigned.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass
class SkewParams:
    eta: float = 100.0
    tau: float = 100.0


def skew_test(phi_l: float, phi_c: float, p: SkewParams) -> bool:
    return phi_l >= p.eta and (phi_l - phi_c) >= p.tau


def detect(workloads: Dict[int, float], p: SkewParams,
           max_pairs: int | None = None) -> List[Tuple[int, int]]:
    """Pair skewed workers with helpers.

    Returns [(skewed, helper), ...].  Skewed workers are considered in
    decreasing workload order; each helper (lowest workload first) is
    assigned to at most one skewed worker (§3.2.1).
    """
    order = sorted(workloads, key=lambda w: -workloads[w])
    assigned: set[int] = set()
    pairs: List[Tuple[int, int]] = []
    for s in order:
        if s in assigned:
            continue
        candidates = [c for c in sorted(workloads, key=lambda w: workloads[w])
                      if c != s and c not in assigned
                      and skew_test(workloads[s], workloads[c], p)]
        if not candidates:
            continue
        h = candidates[0]
        pairs.append((s, h))
        assigned.update((s, h))
        if max_pairs and len(pairs) >= max_pairs:
            break
    return pairs


def load_balancing_ratio(sizes: Sequence[float]) -> float:
    """Paper §3.7.4: min(total_S, total_H) / max(...) — higher is better."""
    lo, hi = min(sizes), max(sizes)
    return 0.0 if hi == 0 else lo / hi
