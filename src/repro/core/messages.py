"""Amber-style control messages (paper §2.3.3, §2.4).

Control messages co-exist with the data plane (training steps) and must take
effect within one *iteration* (paper: one tuple; here: one microbatch).
Every message carries a sequence number; its processing point relative to the
data plane — (step, microbatch) — is recorded in the control-replay log for
fault tolerance (§2.6.2).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Callable, Dict, Optional

_seq = itertools.count()


@dataclasses.dataclass
class ControlMessage:
    kind: str                       # pause|resume|inspect|update|breakpoint|plan|stop
    payload: Any = None
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    response: Any = dataclasses.field(default=None, compare=False)

    def reply(self, value: Any) -> None:
        self.response = value
        self._done.set()

    def wait(self, timeout: Optional[float] = None) -> Any:
        self._done.wait(timeout)
        return self.response


def pause() -> ControlMessage:
    return ControlMessage("pause")


def resume() -> ControlMessage:
    return ControlMessage("resume")


def inspect(what: str = "all") -> ControlMessage:
    return ControlMessage("inspect", what)


def update(**kv) -> ControlMessage:
    return ControlMessage("update", dict(kv))


def set_breakpoint(bp) -> ControlMessage:
    return ControlMessage("breakpoint", bp)


def set_plan(plan_slots, plan_cum, migrations=()) -> ControlMessage:
    return ControlMessage("plan", {"slots": plan_slots, "cum": plan_cum,
                                   "migrations": tuple(migrations)})


def stop() -> ControlMessage:
    return ControlMessage("stop")


@dataclasses.dataclass(frozen=True)
class LogRecord:
    """Replay point of a control message relative to the data plane:
    the paper's <msg, main-thread data seq, (DP msg seq, tuple idx)> maps to
    <msg kind+payload, step, microbatch>."""
    kind: str
    payload: Any
    seq: int
    step: int
    microbatch: int
