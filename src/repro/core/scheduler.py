"""Result-aware choice selection (paper §4.5.2–4.5.4) + the ML mapping.

Two scheduling objectives live here, and every online engine decision is a
choice between them:

* **First-response time (FRT)** — the *interactive* objective: time to the
  FIRST tuple out of the sink.  Every region that must complete before the
  sink's region runs is paid in full; the sink's region contributes only
  its pipeline-fill latency (Figs 4.13–4.15).  Maestro picks the min-FRT
  choice, tie-breaking on materialized bytes (§4.6.3).  This is the serve
  objective: a user is waiting on the first token.
* **Completion time** — the *throughput* objective: total time to drain
  every region.  This is the train-step and kernel-choice objective:
  nobody reads anything until the whole step lands.

``weighted`` variants divide the score by a caller-supplied weight — the
multi-pool serving engine scores each candidate tick as FRT over the summed
priority-class weight of the requests the tick advances, which is how a
high-priority class preempts a low-priority one without a separate queue.

ML mapping (DESIGN.md §2): the same machinery selects the activation
materialization (remat) policy of the training step — regions = {fwd, bwd,
opt}; "materializing" the fwd/bwd edge = saving activations; FRT analogue =
step latency subject to the HBM budget.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Tuple

import networkx as nx

from repro.core.materialization import Edge, enumerate_choices
from repro.core.regions import (Workflow, region_graph, region_of, regions,
                                schedule)


@dataclasses.dataclass
class CostModel:
    parallelism: float = 1.0
    tuple_bytes: float = 64.0


def cardinalities(wf: Workflow) -> Dict[str, float]:
    """Output cardinality per op (topological propagation)."""
    cards: Dict[str, float] = {}
    for n in nx.topological_sort(wf.g):
        op = wf.ops[n]
        inp = sum(cards[p] for p in wf.g.predecessors(n))
        cards[n] = op.source_cardinality if wf.g.in_degree(n) == 0 \
            else op.selectivity * inp
    return cards


def region_full_time(wf: Workflow, region: FrozenSet[str],
                     cards: Dict[str, float], cm: CostModel) -> float:
    t = 0.0
    for n in region:
        op = wf.ops[n]
        inp = sum(cards[p] for p in wf.g.predecessors(n)) or \
            op.source_cardinality
        t += inp * op.cost_per_tuple / cm.parallelism
    return t


def region_first_tuple_time(wf: Workflow, region: FrozenSet[str],
                            cm: CostModel) -> float:
    """Pipeline-fill latency ~ per-tuple cost along the longest path."""
    sub = wf.g.subgraph(region)
    best = 0.0
    for n in region:
        if sub.in_degree(n) == 0:
            for m in region:
                if sub.out_degree(m) == 0:
                    for p in nx.all_simple_paths(sub, n, m):
                        best = max(best, sum(wf.ops[x].cost_per_tuple
                                             for x in p))
                    best = max(best, wf.ops[n].cost_per_tuple)
    return best / cm.parallelism


def first_response_time(wf: Workflow, choice: FrozenSet[Edge],
                        cm: CostModel) -> float:
    w = wf.materialize(choice)
    regs = regions(w)
    rg = region_graph(w)
    cards = cardinalities(w)
    sinks = w.sinks()
    # multiple sink-feeding regions (Fig 4.14/4.15): min over sinks
    best = float("inf")
    for s in sinks:
        rs = region_of(regs, s)
        upstream = nx.ancestors(rg, rs)
        t = sum(region_full_time(w, r, cards, cm) for r in upstream)
        t += region_first_tuple_time(w, rs, cm)
        best = min(best, t)
    return best


def materialized_bytes(wf: Workflow, choice: FrozenSet[Edge],
                       cm: CostModel) -> float:
    cards = cardinalities(wf)
    return sum(cards[u] * cm.tuple_bytes for u, _ in choice)


def choose(wf: Workflow, cm: CostModel) -> Tuple[FrozenSet[Edge], dict]:
    """Result-aware materialization selection: min FRT, then min bytes."""
    options = enumerate_choices(wf)
    scored = []
    for c in options:
        scored.append((first_response_time(wf, c, cm),
                       materialized_bytes(wf, c, cm), c))
    scored.sort(key=lambda x: (x[0], x[1]))
    frt, mbytes, best = scored[0]
    return best, {"frt": frt, "bytes": mbytes,
                  "all": [(f, b, sorted(c)) for f, b, c in scored]}


def completion_time(wf: Workflow, cm: CostModel) -> float:
    """Total time to drain the workflow: every region paid in full.  The
    engine's *throughput* objective — compare with ``first_response_time``,
    the *interactive* objective; the online scheduler picks which of the two
    to minimize depending on whether a user is waiting (result-awareness at
    the job level)."""
    cards = cardinalities(wf)
    return sum(region_full_time(wf, r, cards, cm) for r in regions(wf))


def weighted_first_response_time(wf: Workflow, choice: FrozenSet[Edge],
                                 cm: CostModel,
                                 weight: float = 1.0) -> float:
    """FRT scaled by urgency: candidates serving more (or heavier) waiting
    requests score lower.  ``weight`` is the summed priority-class weight of
    the requests whose first response the candidate advances; weight 1.0 is
    plain FRT, so single-class scheduling falls out unchanged."""
    return first_response_time(wf, choice, cm) / max(weight, 1e-9)


def placement_adjusted_frt(frt: float, weight: float = 1.0,
                           load: float = 0.0, xfer: float = 0.0) -> float:
    """Weighted FRT with device-placement terms: ``load`` (busy fraction of
    the candidate's device group) inflates the score multiplicatively — a
    tick on a contended device finishes later than its pool-local EMA says —
    and ``xfer`` (seconds of pending state migration headed at the pool)
    adds the transfer the tick must wait behind.  Both default to zero, so
    unplaced scheduling reduces to ``weighted_first_response_time``
    exactly — the decision-identity the pre-placement tests pin."""
    return (frt * (1.0 + max(load, 0.0)) + max(xfer, 0.0)) / max(weight,
                                                                 1e-9)


def compare_frt(candidates: Dict[str, Workflow], cm: CostModel,
                weight: float = 1.0) -> Tuple[str, Dict[str, float]]:
    """Arbitrate named alternative workflows under (weighted) FRT: returns
    ``(best_name, scores)`` with the minimum-FRT candidate first and every
    candidate's score for the decision audit trail.  This is the
    reuse-vs-recompute comparator: the engine hands it e.g.
    ``{"seed": prefix_seed_workflow(...), "prefill": prefill_workflow(...)}``
    and takes whichever path answers the waiting user first — the §4.5
    min-FRT rule applied to materialized intermediate state instead of tick
    composition.  Ties break on candidate name for determinism."""
    assert candidates, "compare_frt needs at least one candidate"
    scores = {name: weighted_first_response_time(wf, frozenset(), cm, weight)
              for name, wf in candidates.items()}
    best = min(sorted(scores), key=scores.get)
    return best, scores


def score_choices(wf: Workflow, cm: CostModel,
                  objective: str = "frt",
                  weight: float = 1.0) -> List[Tuple[float, float,
                                                     FrozenSet[Edge]]]:
    """Online API: score every materialization choice under an objective
    ('frt' or 'completion'); sorted best-first, tie-broken on bytes.
    ``weight`` divides the score (see ``weighted_first_response_time``) so
    the same API arbitrates between workflows serving different aggregate
    priority weights; the default leaves scores unweighted."""
    assert objective in ("frt", "completion"), objective
    scored = []
    for c in enumerate_choices(wf):
        t = first_response_time(wf, c, cm) if objective == "frt" \
            else completion_time(wf.materialize(c), cm)
        scored.append((t / max(weight, 1e-9),
                       materialized_bytes(wf, c, cm), c))
    scored.sort(key=lambda x: (x[0], x[1]))
    return scored


# ----------------------------------------------------------- SLO grading

def percentile(xs, q: float) -> float:
    """Deterministic nearest-rank percentile (``q`` in [0, 100]) — the
    SLO-grading primitive.  Nearest-rank (not interpolated) so a grade
    computed from N latency samples is exactly reproducible across numpy
    versions and never manufactures a latency no request actually saw.
    Empty input grades as +inf: a scenario that produced no samples for a
    bounded metric must fail the bound, not vacuously pass it."""
    xs = sorted(float(x) for x in xs)
    if not xs:
        return float("inf")
    q = min(max(float(q), 0.0), 100.0)
    rank = max(int(-(-q / 100.0 * len(xs) // 1)), 1)   # ceil, >= 1
    return xs[rank - 1]


@dataclasses.dataclass(frozen=True)
class ServeSLO:
    """One scenario's service-level objective over measured serve behavior.

    All latency bounds are in *virtual ticks* (scheduling rounds), not wall
    seconds: the gauntlet grades scheduling quality, and tick-denominated
    metrics are deterministic across machines where wall-clock ones embed
    the host's speed.  ``None`` disables a bound.  ``scope`` names the
    priority class the bound applies to (``None``: all requests pooled) —
    a scenario lists one SLO per class it cares about."""
    scope: Optional[str] = None       # priority class (None: all requests)
    p50_ttft: Optional[float] = None  # median first-response bound (ticks)
    p99_ttft: Optional[float] = None  # tail first-response bound (ticks)
    min_goodput: Optional[float] = None   # committed tokens per tick, >=
    max_deferred: Optional[int] = None    # aging-bound ceiling (ticks)
    max_dropped: int = 0              # dropped requests allowed (always 0
    #                                   today: the engine never sheds load)


def grade_slo(metrics: Dict[str, float],
              slos: List[ServeSLO]) -> Tuple[bool, Dict[str, str]]:
    """Grade measured scenario metrics against a list of SLOs.

    ``metrics`` carries per-scope keys — ``p50_ttft``/``p99_ttft``/
    ``goodput``/``max_deferred``/``dropped`` for the pooled scope and
    ``<cls>/p50_ttft`` etc. for class scopes (the shape
    ``loadgen.summarize`` emits).  Returns ``(passed, detail)`` where
    ``detail`` maps each checked criterion to ``"pass:<measured>"`` or
    ``"FAIL:<measured>><bound>"`` — the row the gauntlet prints, so a CI
    failure names the violated bound directly.  A bound whose metric is
    missing fails: silence is not compliance."""
    detail: Dict[str, str] = {}
    ok = True

    def check(scope, name, bound, larger_ok=False):
        nonlocal ok
        if bound is None:
            return
        key = f"{scope}/{name}" if scope else name
        v = metrics.get(key)
        good = v is not None and (v >= bound if larger_ok else v <= bound)
        cmp = ">=" if larger_ok else "<="
        if good:
            detail[key] = f"pass:{v:.2f}{cmp}{bound:g}"
        else:
            ok = False
            detail[key] = (f"FAIL:missing{cmp}{bound:g}" if v is None
                           else f"FAIL:{v:.2f}!{cmp}{bound:g}")

    for s in slos:
        check(s.scope, "p50_ttft", s.p50_ttft)
        check(s.scope, "p99_ttft", s.p99_ttft)
        check(s.scope, "goodput", s.min_goodput, larger_ok=True)
        check(s.scope, "max_deferred", s.max_deferred)
        check(s.scope, "dropped", s.max_dropped)
    return ok, detail


# ------------------------------------------------------------- ML mapping

@dataclasses.dataclass
class RematOption:
    name: str                      # none | dots | full
    act_bytes_per_layer: float     # activations persisted per layer
    recompute_flops_factor: float  # extra fwd fraction paid in bwd


def remat_policy(cfg, shape, hbm_bytes_per_device: float,
                 act_bytes_per_layer: Dict[str, float],
                 step_flops: float, peak_flops: float) -> Tuple[str, dict]:
    """Maestro-style result-aware choice of the activation materialization:
    pick the fastest policy whose persisted activations fit the budget."""
    options = [
        RematOption("none", act_bytes_per_layer["none"], 0.0),
        RematOption("dots", act_bytes_per_layer["dots"], 0.30),
        RematOption("full", act_bytes_per_layer["full"], 1.0 / 3.0),
    ]
    scored = []
    for o in options:
        mem = o.act_bytes_per_layer * cfg.num_layers
        time = step_flops * (1 + o.recompute_flops_factor) / peak_flops
        fits = mem <= hbm_bytes_per_device
        scored.append((not fits, time, o.name, mem))
    scored.sort()
    bad, time, name, mem = scored[0]
    return name, {"fits": not bad, "est_time": time, "act_bytes": mem,
                  "all": scored}
