"""Skew-mitigation strategies on the pipelined simulator: Reshape (the
paper's), plus the two baselines it is evaluated against (Flux §3.1.1-style
SBK-only, Flow-Join-style one-shot SBR) and no-mitigation."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import transfer
from repro.core.adaptive import TauAdjuster, tau_prime
from repro.core.estimator import MeanModelEstimator
from repro.core.skew import SkewParams, detect
from repro.core.worker import PipelinedSim


class NoMitigation:
    def on_metrics(self, tick, sim, workloads):
        pass


@dataclasses.dataclass
class FluxStrategy:
    """SBK only, cannot split a hot key (paper §3.1.1 / §3.7.4)."""
    params: SkewParams = dataclasses.field(default_factory=SkewParams)
    initial_delay: int = 2
    done_pairs: set = dataclasses.field(default_factory=set)

    def on_metrics(self, tick, sim: PipelinedSim, workloads):
        if tick < self.initial_delay:
            return
        for s, h in detect(workloads, self.params):
            if (s, h) in self.done_pairs:
                continue
            self.done_pairs.add((s, h))
            key_loads = dict(sim.processed_key)
            target = (workloads[s] - workloads[h]) / 2.0
            sim.set_logic_with_migration(
                lambda logic, s=s, h=h: transfer.sbk_plan(
                    key_loads, s, h, logic, target), [h])


@dataclasses.dataclass
class FlowJoinStrategy:
    """One-shot: detect heavy hitters in an initial window, then split each
    50/50 with a helper forever (no iteration, no load awareness)."""
    detect_window: int = 2
    top_n: int = 2
    fired: bool = False

    def on_metrics(self, tick, sim: PipelinedSim, workloads):
        if self.fired or tick < self.detect_window:
            return
        self.fired = True
        heavy = sorted(sim.processed_key.items(), key=lambda kv: -kv[1])
        order = sorted(workloads, key=lambda w: workloads[w])
        for i, (key, _) in enumerate(heavy[: self.top_n]):
            owner = sim.logic.assignment[key][0][0]
            helper = next((w for w in order
                           if w != owner and workloads[w] < workloads[owner]),
                          None)
            if helper is None:
                helper = next(w for w in order if w != owner)

            def mutate(logic, key=key, owner=owner, helper=helper):
                logic.assignment[key] = [(helper, 0.5), (owner, 1.0)]
            sim.set_logic_with_migration(mutate, [helper])


@dataclasses.dataclass
class ReshapeStrategy:
    """The paper's strategy: iterative two-phase SBR (or SBK), workload
    estimation, optional adaptive tau, migration-time-aware tau'."""
    params: SkewParams = dataclasses.field(default_factory=SkewParams)
    mode: str = "sbr"                      # "sbr" | "sbk"
    first_phase: bool = True
    adaptive_tau: Optional[TauAdjuster] = None
    helpers_per_skewed: int = 1
    initial_delay: int = 2
    # Detection uses queue size phi (§3.2); the phase-2 split fraction uses
    # estimated future INPUT rates (§3.3.1 "percentage load": redirect 9/26 of
    # J6's input).  We estimate per-KEY arrival rates and aggregate them over
    # each worker's owned partition, so the estimate is partition-change-proof.
    key_est: MeanModelEstimator = dataclasses.field(
        default_factory=MeanModelEstimator)
    # (skewed, helper) -> phase; 1 = catching up, 2 = steady
    active: Dict[Tuple[int, int], int] = dataclasses.field(default_factory=dict)
    iterations: int = 0
    migrations: int = 0          # iterations that moved state (phase-1 / SBK)
    _last_key_arr: Dict[object, float] = dataclasses.field(default_factory=dict)

    def _params_now(self, sim: PipelinedSim) -> SkewParams:
        tau = self.adaptive_tau.tau if self.adaptive_tau else self.params.tau
        if sim.migration_ticks:
            # start earlier so migration completes by the time gap == tau
            tau = max(1.0, tau_prime(tau, 0.7, 0.3, sim.proc_rate * sim.n,
                                     sim.migration_ticks))
        return SkewParams(eta=self.params.eta, tau=tau)

    @staticmethod
    def _owner(logic, key) -> int:
        return logic.assignment[key][-1][0]    # remainder-taker = owner

    def _partition_rate(self, sim, worker) -> Tuple[float, float]:
        """(predicted natural input rate of worker's owned keys, eps)."""
        rate, var = 0.0, 0.0
        for k in sim.logic.assignment:
            if self._owner(sim.logic, k) == worker:
                r, e = self.key_est.predict(k)
                rate += r
                if e != float("inf"):
                    var += e * e
        return rate, var ** 0.5

    def on_metrics(self, tick, sim: PipelinedSim, workloads):
        # per-key arrival-rate samples
        sample = {}
        for k in sim.logic.assignment:
            cur = sim.arrived_key.get(k, 0.0)
            sample[k] = cur - self._last_key_arr.get(k, 0.0)
            self._last_key_arr[k] = cur
        if tick > 0:
            self.key_est.add(sample)
        if tick < self.initial_delay:
            return

        # Algorithm 1 runs at every metric collection: steer tau from the
        # current prediction error of the active pairs
        if self.adaptive_tau is not None:
            for (s, h) in list(self.active) or []:
                rs_, es_ = self._partition_rate(sim, s)
                rh_, eh_ = self._partition_rate(sim, h)
                self.adaptive_tau.adjust(workloads[s], workloads[h],
                                         max(es_, eh_))

        # phase 1 -> phase 2 transitions for active pairs
        for (s, h), phase in list(self.active.items()):
            if phase == 1 and workloads[h] >= workloads[s] * 0.95:
                self._start_phase2(sim, s, h)
                self.active[(s, h)] = 2

        p = self._params_now(sim)
        pairs = detect({w: v for w, v in workloads.items()
                        if not any(w in sh for sh in self.active)}, p)
        for s, h in pairs:
            if self.adaptive_tau:
                rs, es = self._partition_rate(sim, s)
                rh, eh = self._partition_rate(sim, h)
                self.adaptive_tau.adjust(workloads[s], workloads[h],
                                         max(es, eh))
            self.iterations += 1
            if self.mode == "sbk":
                target = (workloads[s] - workloads[h]) / 2.0
                key_loads = dict(sim.processed_key)
                sim.set_logic_with_migration(
                    lambda logic, s=s, h=h: transfer.sbk_plan(
                        key_loads, s, h, logic, target), [h])
                self.active[(s, h)] = 2
            elif self.first_phase:
                self.migrations += 1
                sim.set_logic_with_migration(
                    lambda logic, s=s, h=h: transfer.phase1_apply(
                        logic, s, h), [h])
                self.active[(s, h)] = 1
            else:
                self._start_phase2(sim, s, h, migrate=True)
                self.active[(s, h)] = 2

        # steady-state pairs: on re-divergence run another mitigation
        # iteration on the SAME pair with a fresh rate estimate (Fig 3.9).
        # If the helper side is now the hot one (distribution shift), the
        # redirect drops to zero and the pair dissolves so general detection
        # can re-pair both workers.
        for (s, h), phase in list(self.active.items()):
            if phase == 2 and abs(workloads[s] - workloads[h]) >= p.tau:
                self.iterations += 1
                frac = self._start_phase2(sim, s, h)
                if frac <= 0.0:
                    del self.active[(s, h)]

    def _start_phase2(self, sim: PipelinedSim, s: int, h: int,
                      migrate: bool = False) -> None:
        rs, _ = self._partition_rate(sim, s)
        rh, _ = self._partition_rate(sim, h)
        frac = transfer.sbr_fraction(max(rs, 1e-9), rh)
        # paper §3.4.3.1: the next iteration's sample window starts at the
        # last equal-load point — reset so shifts are seen promptly
        self.key_est.reset()

        def mutate(logic, s=s, h=h, frac=frac):
            transfer.sbr_apply(logic, s, h, frac)
        if migrate:
            # state was not moved by a first phase -> pay migration now
            sim.set_logic_with_migration(mutate, [h])
        else:
            sim.change_logic(mutate)
        return frac
