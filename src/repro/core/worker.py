"""Tier-A pipelined-execution substrate (DESIGN.md §2): a deterministic
tick-based simulator of a partitioned operator under pipelined execution.

This is the validation bed on which the paper's algorithms run *verbatim*:
workers with unprocessed input queues (the workload metric phi), an upstream
partitioning logic the controller mutates via (possibly delayed) control
messages, state-migration latency, and per-key processed counts feeding the
"results shown to the user" (result-representativeness curves, Fig 3.16).

Determinism: per-key arrival uses fractional-rate accumulation; SBR record
splitting uses a per-key low-discrepancy (golden ratio) sequence — no RNG, so
every benchmark figure is exactly reproducible.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.transfer import PartitionLogic

GOLDEN = 0.6180339887498949


@dataclasses.dataclass
class PendingAction:
    apply_at: int
    fn: Callable[["PipelinedSim"], None]


class PipelinedSim:
    def __init__(self, n_workers: int,
                 key_rates: Callable[[int], Dict[object, float]],
                 proc_rate: float, logic: PartitionLogic,
                 control_delay: int = 0, migration_ticks: int = 0):
        self.n = n_workers
        self.key_rates = key_rates
        self.proc_rate = proc_rate
        self.logic = logic
        self.control_delay = control_delay
        self.migration_ticks = migration_ticks
        self.tick_no = 0
        self.queues: List[deque] = [deque() for _ in range(n_workers)]
        self.queue_size = [0.0] * n_workers
        self.arrived = [0.0] * n_workers            # cumulative allotted
        self.processed_key: Dict[object, float] = defaultdict(float)
        self.arrived_key: Dict[object, float] = defaultdict(float)
        self.processed = [0.0] * n_workers
        self._frac: Dict[object, float] = defaultdict(float)
        self._ukey: Dict[object, float] = defaultdict(float)
        self._pending: List[PendingAction] = []
        self.migrating_until = [-1] * n_workers     # helper busy w/ migration
        self.total_emitted = 0.0

    # ---------------------------------------------------------- control plane
    def send_control(self, fn: Callable[["PipelinedSim"], None],
                     extra_delay: int = 0) -> None:
        """Controller -> workers message with delivery delay (Fig 3.21)."""
        self._pending.append(PendingAction(
            self.tick_no + self.control_delay + extra_delay, fn))

    def set_logic_with_migration(self, mutate: Callable[[PartitionLogic], None],
                                 helpers: List[int]) -> None:
        """State migration first (M ticks), then the logic change (§3.6.1).
        ``mutate`` edits the partitioning logic IN EFFECT at apply time, so
        concurrent mitigations of different pairs compose instead of
        clobbering each other."""
        m = self.migration_ticks

        def do(sim: "PipelinedSim"):
            for h in helpers:
                sim.migrating_until[h] = sim.tick_no + m

            def swap(sim2: "PipelinedSim"):
                logic = sim2.logic.copy()
                mutate(logic)
                sim2.logic = logic
            sim._pending.append(PendingAction(sim.tick_no + m, swap))
        self.send_control(do)

    def change_logic(self, mutate: Callable[[PartitionLogic], None],
                     extra_delay: int = 0) -> None:
        def do(sim: "PipelinedSim"):
            logic = sim.logic.copy()
            mutate(logic)
            sim.logic = logic
        self.send_control(do, extra_delay)

    # ------------------------------------------------------------------ step
    def workloads(self) -> Dict[int, float]:
        return {w: self.queue_size[w] for w in range(self.n)}

    def _emit(self) -> None:
        rates = self.key_rates(self.tick_no)
        for key, rate in rates.items():
            self._frac[key] += rate
            count = int(self._frac[key])
            if count <= 0:
                continue
            self._frac[key] -= count
            asg = self.logic.assignment[key]
            if len(asg) == 1:
                dests = [(asg[0][0], count)]
            else:
                dests = []
                left = count
                for _ in range(count):
                    self._ukey[key] = (self._ukey[key] + GOLDEN) % 1.0
                    w = self.logic.route(key, self._ukey[key])
                    if dests and dests[-1][0] == w:
                        dests[-1] = (w, dests[-1][1] + 1)
                    else:
                        dests.append((w, 1))
                    left -= 1
            for w, c in dests:
                self.queues[w].append([key, c])
                self.queue_size[w] += c
                self.arrived[w] += c
            self.arrived_key[key] += count
            self.total_emitted += count

    def _process(self) -> None:
        for w in range(self.n):
            if self.migrating_until[w] > self.tick_no:
                continue                       # busy receiving state
            budget = self.proc_rate
            q = self.queues[w]
            while budget > 0 and q:
                key, c = q[0]
                take = min(budget, c)
                self.processed_key[key] += take
                self.processed[w] += take
                self.queue_size[w] -= take
                budget -= take
                if take >= c:
                    q.popleft()
                else:
                    q[0][1] = c - take

    def step(self) -> None:
        due = [a for a in self._pending if a.apply_at <= self.tick_no]
        self._pending = [a for a in self._pending if a.apply_at > self.tick_no]
        for a in sorted(due, key=lambda a: a.apply_at):
            a.fn(self)
        self._emit()
        self._process()
        self.tick_no += 1

    def run(self, ticks: int, strategy=None, metric_interval: int = 1,
            observer: Optional[Callable[["PipelinedSim"], None]] = None):
        for _ in range(ticks):
            if strategy is not None and self.tick_no % metric_interval == 0:
                strategy.on_metrics(self.tick_no, self, self.workloads())
            self.step()
            if observer is not None:
                observer(self)
        return self
