"""Maestro regions (paper §4.4).

A workflow is a DAG of operators; edges are *pipelined* or *blocking* (the
destination produces nothing until that input is fully consumed — e.g. a
HashJoin build input, a sort input).  A **region** is a connected component
over pipelined, non-materialized edges; the **region graph** has an edge
R1 -> R2 per blocking/materialized workflow edge crossing the regions.
A workflow is schedulable iff the region graph is acyclic (self-loops — a
blocking edge inside one region, Fig 4.5/4.8 — are the canonical violation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

import networkx as nx


@dataclasses.dataclass(frozen=True)
class Op:
    name: str
    kind: str = "op"              # scan|filter|join|ml|union|replicate|sink|...
    cost_per_tuple: float = 1.0
    selectivity: float = 1.0      # output cards = selectivity * input cards
    source_cardinality: float = 0.0


class Workflow:
    def __init__(self):
        self.g = nx.DiGraph()
        self.ops: Dict[str, Op] = {}

    def add_op(self, op: Op) -> "Workflow":
        self.ops[op.name] = op
        self.g.add_node(op.name)
        return self

    def add_edge(self, src: str, dst: str, *, blocking: bool = False,
                 materialized: bool = False, port: str = "") -> "Workflow":
        self.g.add_edge(src, dst, blocking=blocking,
                        materialized=materialized, port=port)
        return self

    def copy(self) -> "Workflow":
        wf = Workflow()
        wf.ops = dict(self.ops)
        wf.g = self.g.copy()
        return wf

    def materialize(self, edges: Iterable[Tuple[str, str]]) -> "Workflow":
        wf = self.copy()
        for u, v in edges:
            wf.g[u][v]["materialized"] = True
        return wf

    def pipelined_edges(self) -> List[Tuple[str, str]]:
        return [(u, v) for u, v, d in self.g.edges(data=True)
                if not d["blocking"] and not d["materialized"]]

    def barrier_edges(self) -> List[Tuple[str, str]]:
        return [(u, v) for u, v, d in self.g.edges(data=True)
                if d["blocking"] or d["materialized"]]

    def sinks(self) -> List[str]:
        return [n for n in self.g if self.g.out_degree(n) == 0]

    def sources(self) -> List[str]:
        return [n for n in self.g if self.g.in_degree(n) == 0]


def regions(wf: Workflow) -> List[FrozenSet[str]]:
    ug = nx.Graph()
    ug.add_nodes_from(wf.g.nodes)
    ug.add_edges_from(wf.pipelined_edges())
    return [frozenset(c) for c in nx.connected_components(ug)]


def region_of(regs: List[FrozenSet[str]], op: str) -> FrozenSet[str]:
    for r in regs:
        if op in r:
            return r
    raise KeyError(op)


def region_graph(wf: Workflow) -> nx.DiGraph:
    regs = regions(wf)
    rg = nx.DiGraph()
    rg.add_nodes_from(regs)
    for u, v in wf.barrier_edges():
        ru, rv = region_of(regs, u), region_of(regs, v)
        rg.add_edge(ru, rv)            # self-loop possible (= infeasible)
    return rg


def is_schedulable(wf: Workflow) -> bool:
    rg = region_graph(wf)
    if any(u == v for u, v in rg.edges):
        return False
    return nx.is_directed_acyclic_graph(rg)


def schedule(wf: Workflow) -> List[FrozenSet[str]]:
    """Topological order of regions (the execution schedule, §4.3)."""
    rg = region_graph(wf)
    assert is_schedulable(wf), "region graph has cycles"
    return list(nx.topological_sort(rg))
