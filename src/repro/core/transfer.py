"""Result-aware load transfer (paper §3.3): SBK vs SBR, two-phase transfer.

``PartitionLogic`` is the paper's "partitioning logic at the previous
operator": a mapping key -> [(worker, cumulative fraction)].  SBK moves whole
keys between workers; SBR splits a key's records across workers by fractions.
The two phases:
  phase 1 (catch-up): redirect ALL future input of the skewed worker S to the
      helper H until their queued workloads meet (§3.3.2);
  phase 2 (steady state): split future input so both receive comparable load,
      using the workload estimator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

Assignment = List[Tuple[int, float]]          # [(worker, cum_frac)], cum->1.0


@dataclasses.dataclass
class PartitionLogic:
    assignment: Dict[object, Assignment]

    def route(self, key, u: float) -> int:
        for worker, cum in self.assignment[key]:
            if u < cum:
                return worker
        return self.assignment[key][-1][0]

    def workers_of(self, key) -> List[int]:
        return [w for w, _ in self.assignment[key]]

    def copy(self) -> "PartitionLogic":
        return PartitionLogic({k: list(v) for k, v in self.assignment.items()})

    @staticmethod
    def hash_partition(keys: Sequence, n_workers: int) -> "PartitionLogic":
        return PartitionLogic(
            {k: [(hash(k) % n_workers, 1.0)] for k in keys})

    @staticmethod
    def modulo(keys: Sequence[int], n_workers: int) -> "PartitionLogic":
        return PartitionLogic({k: [(k % n_workers, 1.0)] for k in keys})


def keys_on(logic: PartitionLogic, worker: int) -> List:
    return [k for k, a in logic.assignment.items()
            if any(w == worker for w, _ in a)]


def keys_owned(logic: PartitionLogic, worker: int) -> List:
    """Keys whose OWNER (remainder-taker, last in the assignment) is
    ``worker`` — a worker's partition for load-transfer purposes; keys it
    merely helps with belong to another pair's mitigation."""
    return [k for k, a in logic.assignment.items() if a[-1][0] == worker]


# ------------------------------------------------------------------ SBK / SBR

def sbk_plan(key_loads: Dict[object, float], skewed: int, helper: int,
             logic: PartitionLogic, target: float) -> List:
    """Split-by-keys: choose keys of S (smallest first, never the largest —
    mirroring that SBK cannot split a single hot key) whose combined load
    moves ~``target`` to H.  Returns the moved keys."""
    s_keys = [(k, key_loads.get(k, 0.0)) for k in keys_owned(logic, skewed)]
    s_keys.sort(key=lambda kv: kv[1])
    moved, acc = [], 0.0
    for k, load in s_keys[:-1]:               # keep the hottest on S
        if acc >= target:
            break
        moved.append(k)
        acc += load
    for k in moved:
        logic.assignment[k] = [(helper, 1.0)]
    return moved


def sbr_fraction(phi_s_hat: float, phi_h_hat: float) -> float:
    """Steady-state fraction of S's future input to redirect so both receive
    comparable load:  (phi_S - phi_H) / (2 phi_S), clipped to [0, 1]."""
    if phi_s_hat <= 0:
        return 0.0
    return min(1.0, max(0.0, (phi_s_hat - phi_h_hat) / (2.0 * phi_s_hat)))


def sbr_apply(logic: PartitionLogic, skewed: int, helper: int,
              frac_to_helper: float) -> None:
    """Split every key OWNED by S: ``frac_to_helper`` of records go to H
    (ownership stays with S; re-application recomputes the fraction)."""
    for k in keys_owned(logic, skewed):
        logic.assignment[k] = [(helper, frac_to_helper), (skewed, 1.0)]


def phase1_apply(logic: PartitionLogic, skewed: int, helper: int) -> None:
    """Catch-up: all future input of S goes to H."""
    sbr_apply(logic, skewed, helper, 1.0)


def multi_sbr_apply(logic: PartitionLogic, skewed: int,
                    helpers_frac: List[Tuple[int, float]]) -> None:
    """SBR across multiple helpers: [(helper, frac)], remainder stays on S."""
    cum, asg = 0.0, []
    for h, f in helpers_frac:
        cum += f
        asg.append((h, cum))
    asg.append((skewed, 1.0))
    for k in keys_on(logic, skewed):
        logic.assignment[k] = list(asg)
