"""The Amber controller for the training/serving runtime.

The training loop plays the worker's DP thread: between *microbatches* (the
granulated iteration unit, §2.4.3) it calls ``poll()``, which drains the
mailbox, applies messages, and — when Paused — keeps serving Inspect /
Update / Resume messages *while paused* (§2.4.4), the capability Spark-style
engines lack.  Every applied message is appended to the control-replay log
with its (step, microbatch) point for deterministic recovery (§2.6.2).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.messages import ControlMessage, LogRecord


def _json_safe(x):
    """Durable-log encoding: numpy / jax arrays and the small control-plane
    dataclasses (Migration) become tagged JSON values instead of raising
    TypeError — a dropped ``plan`` record silently breaks §2.6.2 recovery."""
    import dataclasses as _dc

    import numpy as _np
    if isinstance(x, dict):
        return {k: _json_safe(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_json_safe(v) for v in x]
    if isinstance(x, _np.integer):
        return int(x)
    if isinstance(x, _np.floating):
        return float(x)
    if hasattr(x, "__array__") and not isinstance(x, (str, bytes)):
        a = _np.asarray(x)
        return {"__ndarray__": a.tolist(), "dtype": str(a.dtype)}
    if _dc.is_dataclass(x) and not isinstance(x, type):
        return {"__dataclass__": type(x).__name__,
                "fields": {f.name: _json_safe(getattr(x, f.name))
                           for f in _dc.fields(x)}}
    return x


def _json_restore(x):
    if isinstance(x, dict):
        if "__ndarray__" in x:
            import numpy as _np
            return _np.asarray(x["__ndarray__"], dtype=x["dtype"])
        if "__dataclass__" in x:
            from repro.core import breakpoints as _bp
            from repro.core import reshape_moe as _rm
            cls = getattr(_rm, x["__dataclass__"],
                          getattr(_bp, x["__dataclass__"], None))
            fields = {k: _json_restore(v) for k, v in x["fields"].items()}
            return cls(**fields) if cls is not None else fields
        return {k: _json_restore(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_json_restore(v) for v in x]
    return x


class Controller:
    def __init__(self):
        self.mailbox: "queue.Queue[ControlMessage]" = queue.Queue()
        self.paused = False
        self.stopped = False
        self.log: List[LogRecord] = []
        self.breakpoints: List[Any] = []
        self.config_updates: Dict[str, Any] = {}
        self.pending_plan: Optional[dict] = None
        self.pause_latency: List[float] = []     # wall-time send->effect
        self._sent_at: Dict[int, float] = {}
        self.durable_log_path: Optional[str] = None

    def attach_durable_log(self, path: str) -> None:
        """The coordinator's log survives worker crashes (§2.6.2 A1)."""
        self.durable_log_path = path

    @staticmethod
    def read_durable_log(path: str) -> List[LogRecord]:
        import json as _json
        import os as _os
        out: List[LogRecord] = []
        if not _os.path.exists(path):
            return out
        with open(path) as f:
            for line in f:
                d = _json.loads(line)
                d["payload"] = _json_restore(d["payload"])
                out.append(LogRecord(**d))
        return out

    # ------------------------------------------------------------ user side
    def send(self, msg: ControlMessage) -> ControlMessage:
        self._sent_at[msg.seq] = time.monotonic()
        self.mailbox.put(msg)
        return msg

    # ------------------------------------------------------------ loop side
    def _apply(self, msg: ControlMessage, step: int, microbatch: int,
               inspect_fn: Optional[Callable[[str], Any]]) -> None:
        rec = LogRecord(msg.kind, msg.payload, msg.seq, step, microbatch)
        self.log.append(rec)
        if self.durable_log_path and msg.kind in ("update", "plan", "pause",
                                                  "resume", "breakpoint"):
            import json as _json
            d = {"kind": rec.kind, "payload": _json_safe(rec.payload),
                 "seq": rec.seq, "step": rec.step,
                 "microbatch": rec.microbatch}
            try:
                line = _json.dumps(d)
            except TypeError:
                # a payload type _json_safe doesn't model must not kill the
                # worker's poll, but it must not vanish silently either:
                # log a tagged repr and warn — replay will surface it
                import warnings as _w
                d["payload"] = {"__unserializable__": repr(rec.payload)}
                line = _json.dumps(d)
                _w.warn(f"durable log: {rec.kind} payload not "
                        f"JSON-serializable; logged as repr")
            with open(self.durable_log_path, "a") as f:
                f.write(line + "\n")
        if msg.kind == "pause":
            self.paused = True
            t0 = self._sent_at.pop(msg.seq, None)
            if t0 is not None:
                self.pause_latency.append(time.monotonic() - t0)
            msg.reply({"paused_at": (step, microbatch)})
        elif msg.kind == "resume":
            self.paused = False
            msg.reply({"resumed_at": (step, microbatch)})
        elif msg.kind == "inspect":
            msg.reply(inspect_fn(msg.payload) if inspect_fn else None)
        elif msg.kind == "update":
            self.config_updates.update(msg.payload)
            msg.reply(dict(self.config_updates))
        elif msg.kind == "breakpoint":
            self.breakpoints.append(msg.payload)
            msg.reply(len(self.breakpoints))
        elif msg.kind == "plan":
            self.pending_plan = msg.payload
            msg.reply(True)
        elif msg.kind == "stop":
            self.stopped = True
            self.paused = False
            msg.reply(True)

    def poll(self, step: int, microbatch: int,
             inspect_fn: Optional[Callable[[str], Any]] = None,
             block_while_paused: bool = True) -> Dict[str, Any]:
        """Drain mailbox; if paused, keep responding until resumed."""
        while True:
            try:
                while True:
                    msg = self.mailbox.get_nowait()
                    self._apply(msg, step, microbatch, inspect_fn)
            except queue.Empty:
                pass
            if self.paused and block_while_paused and not self.stopped:
                try:
                    msg = self.mailbox.get(timeout=0.05)
                    self._apply(msg, step, microbatch, inspect_fn)
                except queue.Empty:
                    continue
                continue
            break
        updates, self.config_updates = self.config_updates, {}
        plan, self.pending_plan = self.pending_plan, None
        return {"updates": updates, "plan": plan, "stopped": self.stopped}

    # --------------------------------------------------------------- replay
    def is_replaying(self) -> bool:
        """True while logged control messages are still pending re-application
        (recovery); the loop must stay on the granulated path so they land at
        their recorded (step, microbatch) points.  Covers both
        ReplayingController and replay_into-style injection."""
        return bool(getattr(self, "_replay", None))

    def replay_records(self, after_step: int) -> List[LogRecord]:
        """Records to re-apply when recovering from a checkpoint taken at the
        end of ``after_step`` (§2.6.2 recovery)."""
        return [r for r in self.log if r.step > after_step]


def replay_into(controller: "Controller", records: List[LogRecord]) -> None:
    """Pre-load a recovered controller so the loop re-applies messages at
    their original (step, microbatch) points."""
    controller._replay = sorted(records, key=lambda r: (r.step, r.microbatch,
                                                        r.seq))


class ReplayingController(Controller):
    """Controller that injects logged messages at their recorded points —
    used during recovery; new live messages are held until replay is done
    (paper: 'the coordinator holds new control messages ... until the worker
    has replayed all its control-replay log records')."""

    def __init__(self, records: List[LogRecord]):
        super().__init__()
        self._replay = sorted(records, key=lambda r: (r.step, r.microbatch,
                                                      r.seq))

    def poll(self, step: int, microbatch: int, inspect_fn=None,
             block_while_paused: bool = True):
        while self._replay and (self._replay[0].step, self._replay[0].microbatch) <= (step, microbatch):
            r = self._replay.pop(0)
            msg = ControlMessage(r.kind, r.payload)
            if r.kind == "pause":
                # replayed pause+resume pairs cancel; state effects
                # (update/plan) are what must be reproduced exactly
                continue
            if r.kind == "resume":
                continue
            self._apply(msg, step, microbatch, inspect_fn)
        if self._replay:
            # hold live messages until replay completes
            updates, self.config_updates = self.config_updates, {}
            plan, self.pending_plan = self.pending_plan, None
            return {"updates": updates, "plan": plan, "stopped": self.stopped}
        return super().poll(step, microbatch, inspect_fn, block_while_paused)
