"""State migration by mutability class (paper §3.5, Table 3.1).

* immutable state (HashJoin probe): replicate at the helper, then re-route.
* mutable + SBK (group-by): synchronized move (pause-migrate-resume or
  markers) — the helper's state for the moved keys is the skewed worker's.
* mutable + SBR (range-sort): the same scope's value is *scattered* across
  workers; blocking operators merge scattered parts on END markers (§3.5.4).

The classes below implement real operator state (hash tables / sorted runs /
aggregates) over the simulator's record streams, plus the merge protocol.
Migration cost (bytes) feeds tau' (§3.6.1) and multi-helper selection.
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

MUTABILITY = {
    # operator phase -> mutable?
    ("hashjoin", "probe"): False,
    ("set_difference", "probe"): False,
    ("set_intersection", "probe"): False,
    ("hashjoin", "build"): True,
    ("groupby", "agg"): True,
    ("sort", "insert"): True,
    ("set_union", "insert"): True,
}


def is_mutable(op: str, phase: str) -> bool:
    return MUTABILITY[(op, phase)]


@dataclasses.dataclass
class MigrationCost:
    bytes_moved: int
    seconds: float


def migration_time(state_bytes: int, bandwidth_bps: float,
                   serialization_overhead: float = 1.1) -> float:
    return state_bytes * serialization_overhead / bandwidth_bps


# --------------------------------------------------------------- operators

class HashJoinProbe:
    """Immutable-state op: build table fixed during probe phase."""

    def __init__(self, build: Dict[object, List]):
        self.build = build                     # scope -> build tuples

    def state_bytes(self, keys) -> int:
        return sum(len(self.build.get(k, ())) * 8 for k in keys)

    def replicate_to(self, other: "HashJoinProbe", keys) -> MigrationCost:
        moved = 0
        for k in keys:
            if k in self.build:
                other.build[k] = list(self.build[k])
                moved += len(self.build[k]) * 8
        return MigrationCost(moved, 0.0)

    def process(self, key, value):
        return [(value, b) for b in self.build.get(key, ())]


class GroupByAgg:
    """Mutable-state op, SBK-migratable with synchronization (§3.5.3)."""

    def __init__(self):
        self.agg: Dict[object, float] = defaultdict(float)

    def process(self, key, value):
        self.agg[key] += value

    def state_bytes(self, keys) -> int:
        return sum(16 for k in keys if k in self.agg)

    def migrate_keys_to(self, other: "GroupByAgg", keys) -> MigrationCost:
        moved = 0
        for k in list(keys):
            if k in self.agg:
                other.agg[k] += self.agg.pop(k)
                moved += 16
        return MigrationCost(moved, 0.0)


class RangeSortWorker:
    """Mutable-state op under SBR: scattered state + END-marker merge
    (paper Fig 3.11).  Each worker keeps a sorted run per scope (range)."""

    def __init__(self, wid: int):
        self.wid = wid
        self.runs: Dict[object, List] = defaultdict(list)   # scope -> sorted
        self.ended_upstreams: set = set()
        self.output: Optional[List] = None

    def process(self, scope, value):
        bisect.insort(self.runs[scope], value)

    def state_bytes(self, scopes) -> int:
        return sum(len(self.runs.get(s, ())) * 8 for s in scopes)

    def on_end_marker(self, upstream: int, n_upstreams: int,
                      scope_owner: Dict[object, "RangeSortWorker"]):
        """When END markers from all upstreams arrive, ship scattered parts
        of scopes owned elsewhere to their owners (Fig 3.11(e,f))."""
        self.ended_upstreams.add(upstream)
        if len(self.ended_upstreams) < n_upstreams:
            return MigrationCost(0, 0.0)
        moved = 0
        for scope, run in list(self.runs.items()):
            owner = scope_owner[scope]
            if owner is not self:
                for v in run:
                    bisect.insort(owner.runs[scope], v)
                moved += len(run) * 8
                del self.runs[scope]
        return MigrationCost(moved, 0.0)

    def finalize(self, scope_order: List) -> List:
        out: List = []
        for s in scope_order:
            out.extend(self.runs.get(s, ()))
        self.output = out
        return out


def merged_sorted_output(workers: List[RangeSortWorker],
                         scope_order: List) -> List:
    """Concatenate per-owner outputs in range order — must be fully sorted
    iff the scattered-state merge was correct (test invariant)."""
    out: List = []
    for s in scope_order:
        for w in workers:
            out.extend(w.runs.get(s, ()))
    return out
