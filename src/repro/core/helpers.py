"""Multi-helper selection (paper §3.6.2).

Given helper candidates h_1..h_c in increasing workload order, add helpers
while chi = min(LR_max, F) keeps increasing, where
    LR_max = (f_S - avg_{w in {S,h..}} f_w) * T     (ideal load reduction)
    F      = (L - M * t) * f_hat_S                  (S's future tuples left
                                                     after state migration)
"""
from __future__ import annotations

from typing import Dict, List, Tuple


def lr_max(f_s: float, f_helpers: List[float], total_tuples: float) -> float:
    fs = [f_s] + list(f_helpers)
    return (f_s - sum(fs) / len(fs)) * total_tuples


def future_after_migration(tuples_left: float, migration_secs: float,
                           tuples_per_sec: float, f_hat_s: float) -> float:
    return max(0.0, (tuples_left - migration_secs * tuples_per_sec) * f_hat_s)


def choose_helpers(f_s: float, candidates: List[Tuple[int, float]],
                   total_tuples: float, tuples_left: float,
                   tuples_per_sec: float,
                   migration_secs_for: "callable") -> List[int]:
    """candidates: [(worker, workload fraction)] in increasing workload order.
    ``migration_secs_for(n)`` estimates migration time with n helpers.
    Returns the chosen helper ids (paper: stop right before chi decreases)."""
    chosen: List[int] = []
    fracs: List[float] = []
    best_chi = -1.0
    for w, fw in candidates:
        trial_f = fracs + [fw]
        m = migration_secs_for(len(trial_f))
        chi = min(lr_max(f_s, trial_f, total_tuples),
                  future_after_migration(tuples_left, m, tuples_per_sec, f_s))
        if chi <= best_chi:
            break
        best_chi = chi
        chosen.append(w)
        fracs.append(fw)
    return chosen
