"""Reshape -> MoE expert-parallel integration (Tier B, DESIGN.md §2).

The paper's abstractions mapped onto synchronous expert-parallel training:

  worker            = EP rank (device column of the "model" mesh axis)
  partition         = the logical experts whose *home slot* lives on a rank
  workload phi      = EMA of tokens routed to a rank per step (from the free
                      in-layer metrics) + overflow backlog counter
  partitioning logic= the RoutingPlan arrays (jittable step inputs)
  SBR               = split a hot expert's tokens between its home slot and a
                      replica in a helper rank's spare slot
  SBK               = move a whole expert into a helper rank's spare slot
  state migration   = copying the expert's weights (+ optimizer moments) into
                      the spare slot; cost enters tau' (§3.6.1)
  phase 1           = boosted redirect fraction while the skewed rank drains
                      its overflow backlog; phase 2 = estimator-based fraction

Slot layout interleaves one spare per rank:  rank d owns slots
[d*(epd+1), (d+1)*(epd+1)); the last one is its spare.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adaptive import TauAdjuster, tau_prime
from repro.core.skew import SkewParams


@dataclasses.dataclass(frozen=True)
class SlotLayout:
    num_experts: int
    ep_ranks: int

    @property
    def experts_per_rank(self) -> int:
        assert self.num_experts % self.ep_ranks == 0
        return self.num_experts // self.ep_ranks

    @property
    def slots_per_rank(self) -> int:
        return self.experts_per_rank + 1          # one spare per rank

    @property
    def num_slots(self) -> int:
        return self.slots_per_rank * self.ep_ranks

    def home_slot(self, e: int) -> int:
        epd = self.experts_per_rank
        return (e // epd) * self.slots_per_rank + (e % epd)

    def spare_slot(self, rank: int) -> int:
        return rank * self.slots_per_rank + self.experts_per_rank

    def rank_of_slot(self, s: int) -> int:
        return s // self.slots_per_rank

    def rank_of_expert(self, e: int) -> int:
        return e // self.experts_per_rank


def _cum_to_fracs(cum: np.ndarray) -> np.ndarray:
    """Cumulative split fractions -> per-replica fractions along the last
    axis, in float64.  Negative diffs (malformed rows) contribute 0, matching
    the ``frac > 0`` guard of the loop formulation.  Hand-rolled instead of
    ``np.diff(..., prepend=0)`` — this sits on the controller's decision hot
    path and np.diff's prepend allocation costs ~10x the subtraction."""
    c = cum.astype(np.float64)
    f = np.empty_like(c)
    f[..., 0] = c[..., 0]
    f[..., 1:] = c[..., 1:] - c[..., :-1]
    np.maximum(f, 0.0, out=f)
    return f


@dataclasses.dataclass
class Migration:
    layer: int
    src_slot: int
    dst_slot: int


@dataclasses.dataclass
class MitigationEvent:
    layer: int
    skewed_rank: int
    helper_rank: int
    hot_expert: int
    fraction: float
    phase: int
    migration: Optional[Migration]


class MoEReshaper:
    """Host-side controller logic: observe per-step metrics, emit new plans
    + expert-state migrations between steps (the fast control path)."""

    def __init__(self, cfg: ArchConfig, n_moe_layers: int, ep_ranks: int,
                 params: Optional[SkewParams] = None,
                 ema_beta: float = 0.8, adaptive: Optional[TauAdjuster] = None,
                 phase1_steps: int = 2, mode: str = "sbr",
                 migration_steps: float = 0.0):
        self.cfg = cfg
        self.nl = n_moe_layers
        self.layout = SlotLayout(cfg.moe.num_experts, ep_ranks)
        # fresh instance per reshaper: a shared default would leak tau
        # updates (TrainLoop._apply_updates mutates params.tau in place)
        # into every reshaper constructed afterwards
        self.params = params if params is not None \
            else SkewParams(eta=0.0, tau=0.25)  # tau as FRACTION of mean load
        self.ema_beta = ema_beta
        self.adaptive = adaptive
        self.phase1_steps = phase1_steps
        self.mode = mode
        self.migration_steps = migration_steps
        e, r = cfg.moe.num_experts, cfg.moe.max_replicas
        self.plan_slots = np.zeros((n_moe_layers, e, r), np.int32)
        for le in range(e):
            self.plan_slots[:, le, :] = self.layout.home_slot(le)
        self.plan_cum = np.ones((n_moe_layers, e, r), np.float32)
        self._ema_expert = None               # [L, E]
        self._ema_var = None
        self.backlog = np.zeros((n_moe_layers, ep_ranks), np.float64)
        # spare-slot ownership: (layer, rank) -> expert replica hosted there
        self.spare_owner: Dict[Tuple[int, int], int] = {}
        # experts under active mitigation: (layer, expert) -> phase1 steps left
        self.active: Dict[Tuple[int, int], int] = {}
        self.events: List[MitigationEvent] = []
        self.iterations = 0
        self._replica_map: Optional[Dict] = None   # per-step index, see step()
        self._loads_cache: Optional[np.ndarray] = None  # set by observe()
        self._plan_cache = None   # (fracs, flat rank idx); see _plan_derived

    # ------------------------------------------------------------- observe
    def observe(self, expert_counts: np.ndarray,
                dropped_per_layer: Optional[np.ndarray] = None) -> None:
        """expert_counts [L, E] tokens routed per logical expert this step."""
        x = np.asarray(expert_counts, np.float64)
        if self._ema_expert is None:
            self._ema_expert = x.copy()
            self._ema_var = np.zeros_like(x)
        else:
            d = x - self._ema_expert
            self._ema_expert = self.ema_beta * self._ema_expert + \
                (1 - self.ema_beta) * x
            self._ema_var = self.ema_beta * self._ema_var + \
                (1 - self.ema_beta) * d * d
        self._loads_cache = None
        if dropped_per_layer is not None:
            # attribute overflow to the currently-loaded rank
            loads = self.rank_loads_all()                     # [L, ranks]
            top = np.argmax(loads, axis=1)
            self.backlog[np.arange(self.nl), top] += np.asarray(
                dropped_per_layer, np.float64)
            # plan and EMA are untouched between here and the next step(),
            # so these loads double as its pre-maintain loads
            self._loads_cache = loads

    def _plan_derived(self):
        """Plan-dependent arrays for rank_loads_all, cached until the next
        plan write (every plan mutation goes through a method that clears
        ``_plan_cache``): per-replica fracs [L, E, R] and the flattened
        layer-major rank index for bincount."""
        if self._plan_cache is None:
            nr = self.layout.ep_ranks
            fracs = _cum_to_fracs(self.plan_cum)              # [L, E, R]
            ranks = self.plan_slots // self.layout.slots_per_rank
            l_idx = (np.arange(self.nl) * nr)[:, None, None]
            self._plan_cache = (fracs, (l_idx + ranks).ravel())
        return self._plan_cache

    def rank_loads_all(self) -> np.ndarray:
        """Predicted tokens/step per EP rank [L, ranks] under the CURRENT
        plan — one whole-array pass over [L, E, R], no Python loops."""
        nr = self.layout.ep_ranks
        fracs, flat = self._plan_derived()
        w = self._ema_expert[:, :, None] * fracs
        return np.bincount(flat, weights=w.ravel(),
                           minlength=self.nl * nr).reshape(self.nl, nr)

    def rank_loads(self, layer: int) -> np.ndarray:
        """Single-layer view of :meth:`rank_loads_all`."""
        nr = self.layout.ep_ranks
        fracs = _cum_to_fracs(self.plan_cum[layer])           # [E, R]
        ranks = self.plan_slots[layer] // self.layout.slots_per_rank
        w = self._ema_expert[layer][:, None] * fracs
        return np.bincount(ranks.ravel(), weights=w.ravel(), minlength=nr)

    # ------------------------------------------------------------ mitigate
    def _current_frac(self, layer: int, expert: int) -> float:
        """TOTAL fraction of this expert's tokens currently redirected away
        from its home slot (0 under the identity plan)."""
        home = self.layout.home_slot(expert)
        fracs = _cum_to_fracs(self.plan_cum[layer, expert])
        return float(fracs[self.plan_slots[layer, expert] != home].sum())

    def _set_split(self, layer: int, expert: int, helper_slot: int,
                   frac: float) -> None:
        home = self.layout.home_slot(expert)
        r = self.plan_slots.shape[2]
        self._plan_cache = None
        self._loads_cache = None
        self.plan_slots[layer, expert, 0] = helper_slot
        self.plan_slots[layer, expert, 1:] = home
        cum = np.ones(r, np.float32)
        cum[0] = frac
        self.plan_cum[layer, expert] = cum

    def _move_expert(self, layer: int, expert: int, dst_slot: int) -> None:
        self._plan_cache = None
        self._loads_cache = None
        self.plan_slots[layer, expert, :] = dst_slot
        self.plan_cum[layer, expert, :] = 1.0

    def step(self) -> Tuple[np.ndarray, np.ndarray, List[Migration]]:
        """Run detection/mitigation; returns (plan_slots, plan_cum,
        migrations to apply to params/opt state *before* the next step).

        Layers are independent (each touches only its own plan rows, backlog
        row and loads row), so the maintain phase is batched across ALL
        active mitigations of all layers in one whole-array re-waterfill,
        followed by per-layer detection against post-maintain loads."""
        migrations: List[Migration] = []
        if self._ema_expert is None:
            return self.plan_slots, self.plan_cum, migrations
        # per-step replica index: one spare_owner pass instead of one scan
        # per _replicas_of call.  Valid for the whole step: detection only
        # ADDS (layer, rank) keys for its own layer, and each layer reads
        # its replicas before writing them.
        self._replica_map = {}
        for (ll, rank), owner in self.spare_owner.items():
            self._replica_map.setdefault((ll, owner), []).append(rank)
        try:
            loads_all = self._loads_cache if self._loads_cache is not None \
                else self.rank_loads_all()
            self._loads_cache = None
            means = np.maximum(loads_all.mean(1), 1e-9)
            self._maintain_active(loads_all, means)
            loads_all = self.rank_loads_all()
            eps_all = np.sqrt(self._ema_var.mean(1)) / means
            deferred: list = []
            pending_events: list = []
            # cross-layer precheck of eq 3.1/3.2 (exact complement of the
            # per-layer skip test).  Invalid with an adaptive adjuster: its
            # tau mutates as earlier layers fire.
            fire = None
            if self.adaptive is None:
                tau = self.params.tau
                if self.migration_steps:
                    tau = max(0.01, tau_prime(tau, 0.6, 0.4, 1.0,
                                              self.migration_steps))
                lmax = loads_all.max(1)
                fire = (lmax >= self.params.eta) & \
                    ((lmax - loads_all.min(1)) / means >= tau)
            for l in range(self.nl):
                if fire is not None and not fire[l]:
                    continue
                migrations.extend(self._detect_layer(
                    l, loads_all[l], means[l], eps_all[l], deferred,
                    pending_events))
            if deferred:
                self._waterfill_batch(deferred, loads_all)
            for (l, s, h, hot, phase, mig) in pending_events:
                self.events.append(MitigationEvent(
                    l, s, h, hot, float(self.plan_cum[l, hot, 0]), phase,
                    mig))
        finally:
            self._replica_map = None
        return self.plan_slots.copy(), self.plan_cum.copy(), migrations

    def _maintain_active(self, loads_all: np.ndarray,
                         means: np.ndarray) -> None:
        """Re-waterfill every active mitigation with its stable helper set;
        phase-1 boost while that rank's backlog drains (two phases).  All
        entries are gathered first, then written by one batched waterfill.
        Entries of the same (layer, rank) drain the shared backlog
        sequentially in ``active`` insertion order, so each entry's boost
        sees the backlog left by its predecessors — matching the sequential
        formulation (see ``LoopReshaper``) bit for bit."""
        if not self.active:
            return
        entries = []
        drained: Dict[Tuple[int, int], int] = {}
        for (l, hot), left in list(self.active.items()):
            s = self.layout.rank_of_expert(hot)
            helpers = self._replicas_of(l, hot)
            if not helpers:
                del self.active[(l, hot)]
                continue
            j = drained.get((l, s), 0)
            boost = 1.5 if (left > 0 and
                            self.backlog[l, s] - j * means[l] > 0) else 1.0
            drained[(l, s)] = j + 1
            entries.append((l, hot, helpers, boost))
            self.active[(l, hot)] = max(0, left - 1)
        for (l, s), k in drained.items():
            self.backlog[l, s] = max(0.0, self.backlog[l, s] - k * means[l])
        if entries:
            self._waterfill_batch(entries, loads_all)

    def _waterfill_batch(self, entries, loads_all: np.ndarray) -> None:
        """Vectorized ``_waterfill`` over N (layer, hot, helpers, boost)
        entries — each entry reads and writes only its own [R] plan row, so
        the batch is order-independent; every arithmetic step mirrors the
        per-entry version in the same reduction order (bit-exact)."""
        lay = self.layout
        r = self.plan_slots.shape[2]
        n = len(entries)
        h_max = max(len(e[2]) for e in entries)
        l_arr = np.fromiter((e[0] for e in entries), np.int64, n)
        hot = np.fromiter((e[1] for e in entries), np.int64, n)
        boost = np.fromiter((e[3] for e in entries), np.float64, n)
        n_h = np.fromiter((len(e[2]) for e in entries), np.int64, n)
        hr = np.zeros((n, h_max), np.int64)
        for i, e in enumerate(entries):
            hr[i, :len(e[2])] = e[2]
        valid = np.arange(h_max)[None, :] < n_h[:, None]
        phi = np.maximum(self._ema_expert[l_arr, hot], 1e-9)
        rows_s = self.plan_slots[l_arr, hot]                  # [N, R]
        fracs = _cum_to_fracs(self.plan_cum[l_arr, hot])      # [N, R]
        s_rank = hot // lay.experts_per_rank
        home = s_rank * lay.slots_per_rank + hot % lay.experts_per_rank
        redirected = ((rows_s != home[:, None]) * fracs).sum(1)
        base_s = loads_all[l_arr, s_rank] - phi * (1.0 - redirected)
        spare = hr * lay.slots_per_rank + lay.experts_per_rank  # [N, H]
        on_spare = rows_s[:, None, :] == spare[:, :, None]      # [N, H, R]
        contrib = phi[:, None] * (on_spare * fracs[:, None, :]).sum(-1)
        bases = np.where(valid, loads_all[l_arr[:, None], hr] - contrib, 0.0)
        total = phi + base_s + bases.sum(1)
        per = total / (1.0 + n_h)
        f = np.maximum(0.0, per[:, None] - bases) / phi[:, None]
        f = np.where(valid, np.minimum(1.0, f * boost[:, None]), 0.0)
        ftot = f.sum(1)
        over = ftot > 1.0
        f = np.where(over[:, None], f / np.where(over, ftot, 1.0)[:, None], f)
        # plan rows: [spare(h1), ..., spare(h_nsp), home, home, ...]
        n_sp = np.minimum(n_h, r - 1)
        kcols = min(h_max, r - 1)                 # n_sp <= kcols always
        use = np.arange(kcols)[None, :] < n_sp[:, None]
        slots_row = np.empty((n, r), np.int32)
        slots_row[:] = home[:, None]
        np.copyto(slots_row[:, :kcols], spare[:, :kcols], where=use)
        cum_row = np.ones((n, r), np.float32)
        np.copyto(cum_row[:, :kcols],
                  np.minimum(1.0, np.cumsum(f[:, :kcols], axis=1)),
                  where=use)
        self._plan_cache = None
        self._loads_cache = None
        self.plan_slots[l_arr, hot] = slots_row
        self.plan_cum[l_arr, hot] = cum_row

    def _replicas_of(self, l: int, e: int) -> List[int]:
        """Spare-slot ranks currently hosting a replica of expert e."""
        if self._replica_map is not None:
            return list(self._replica_map.get((l, e), ()))
        return [rank for (ll, rank), owner in self.spare_owner.items()
                if ll == l and owner == e]

    def _waterfill(self, l: int, hot: int, helper_ranks: List[int],
                   loads: np.ndarray, boost: float = 1.0) -> None:
        """Split the hot expert across its home rank + helper spares so all
        participating ranks approach the common level (§3.6.2 extended to
        SBR fractions).  ``boost`` > 1 over-redirects (phase-1 catch-up).
        Single-entry wrapper over ``_waterfill_batch`` — one copy of the
        numerically delicate waterfill math."""
        loads_all = np.zeros((l + 1, loads.shape[0]))
        loads_all[l] = loads
        self._waterfill_batch([(l, hot, list(helper_ranks), boost)],
                              loads_all)

    def _detect_layer(self, l: int, loads: np.ndarray, mean: float,
                      eps: float, deferred: list,
                      pending_events: list) -> List[Migration]:
        """Detect new skew on layer ``l`` (eq 3.1/3.2 at rank granularity)
        against post-maintain ``loads``; ``mean``/``eps`` come from the
        pre-maintain loads, matching the sequential formulation.  The SBR
        waterfill is appended to ``deferred`` (one batched write in
        ``step``) — layers never read each other's plan rows, so deferral
        is observationally identical to writing in place."""
        out: List[Migration] = []
        tau = self.adaptive.tau if self.adaptive else self.params.tau
        if self.migration_steps:
            tau = max(0.01, tau_prime(tau, 0.6, 0.4, 1.0,
                                      self.migration_steps))
        max_helpers = self.plan_slots.shape[2] - 1
        s = int(np.argmax(loads))
        if loads[s] < self.params.eta or (loads[s] - loads.min()) / mean < tau:
            return out
        # experts homed on rank s are contiguous: [s*epd, (s+1)*epd)
        epd = self.layout.experts_per_rank
        seg = self._ema_expert[l, s * epd:(s + 1) * epd]
        hot = int(s * epd + np.argmax(seg))
        if self.adaptive:
            self.adaptive.adjust(loads[s] / mean, loads.min() / mean, eps)
        self.iterations += 1

        if self.mode == "sbk":
            # move the smallest expert worth ~the gap (cannot split the hot
            # key — the Flux-style limitation the paper contrasts with)
            move = int(s * epd + np.argmin(seg))
            h = int(np.argmin(loads))
            if (l, h) not in self.spare_owner:
                spare = self.layout.spare_slot(h)
                self.spare_owner[(l, h)] = move
                out.append(Migration(l, self.layout.home_slot(move), spare))
                self._move_expert(l, move, spare)
                self.events.append(MitigationEvent(l, s, h, move, 1.0, 2,
                                                   out[-1]))
            return out

        # ---- SBR: (re)build the helper set for the hot expert — reuse its
        # existing replicas, extend with least-loaded ranks w/ free spares
        helpers = self._replicas_of(l, hot)
        order = [int(h) for h in np.argsort(loads) if int(h) != s]
        phi = max(self._ema_expert[l, hot], 1e-9)
        for h in order:
            if len(helpers) >= max_helpers:
                break
            if h in helpers:
                continue
            if self.spare_owner.get((l, h)) not in (None, hot):
                continue                      # spare already hosts another
            # does adding this helper reduce the common level? (chi logic)
            if loads[h] >= loads[s]:
                break
            helpers.append(h)
            if (phi + sum(loads[x] for x in helpers + [s])) / \
                    (len(helpers) + 1) <= mean * (1 + tau / 2):
                break
        if not helpers:
            return out
        for h in helpers:
            if self.spare_owner.get((l, h)) != hot:
                self.spare_owner[(l, h)] = hot
                out.append(Migration(l, self.layout.home_slot(hot),
                                     self.layout.spare_slot(h)))
        has_backlog = self.backlog[l, s] > 0
        deferred.append((l, hot, helpers,
                         1.5 if has_backlog else 1.0))
        self.active[(l, hot)] = self.phase1_steps if has_backlog else 0
        pending_events.append((l, s, helpers[0], hot,
                               1 if has_backlog else 2,
                               out[-1] if out else None))
        return out


# ----------------------------------------------------------- loop references
# Loop-based formulations of the vectorized hot-path methods above, kept as
# the executable spec: the regression tests assert the whole-array versions
# match these on randomized plans, and the reshaper-latency benchmark uses
# them as the pre-vectorization baseline.  They read reshaper state but never
# mutate it.

def rank_loads_loop(rs: "MoEReshaper", layer: int) -> np.ndarray:
    loads = np.zeros(rs.layout.ep_ranks)
    e = rs.cfg.moe.num_experts
    for le in range(e):
        pred = rs._ema_expert[layer, le]
        cum_prev = 0.0
        for r in range(rs.plan_slots.shape[2]):
            cum = float(rs.plan_cum[layer, le, r])
            frac = cum - cum_prev
            if frac > 0:
                rank = rs.layout.rank_of_slot(
                    int(rs.plan_slots[layer, le, r]))
                loads[rank] += pred * frac
            cum_prev = cum
    return loads


def current_frac_loop(rs: "MoEReshaper", layer: int, expert: int) -> float:
    home = rs.layout.home_slot(expert)
    prev, redirected = 0.0, 0.0
    for slot, cum in zip(rs.plan_slots[layer, expert],
                         rs.plan_cum[layer, expert]):
        frac = float(cum) - prev
        prev = float(cum)
        if frac > 0 and int(slot) != home:
            redirected += frac
    return redirected


def waterfill_row_loop(rs: "MoEReshaper", l: int, hot: int,
                       helper_ranks: List[int], loads: np.ndarray,
                       boost: float = 1.0):
    """Returns the (slots_row, cum_row) that ``_waterfill`` would write."""
    s = rs.layout.rank_of_expert(hot)
    phi = max(rs._ema_expert[l, hot], 1e-9)
    base_s = loads[s] - phi * (1.0 - current_frac_loop(rs, l, hot))
    bases = []
    cur_slots = list(rs.plan_slots[l, hot])
    cur_cum = list(rs.plan_cum[l, hot])
    for h in helper_ranks:
        contrib = 0.0
        prev = 0.0
        for slot, cum in zip(cur_slots, cur_cum):
            frac = float(cum) - prev
            prev = float(cum)
            if frac > 0 and rs.layout.rank_of_slot(int(slot)) == h and \
                    int(slot) == rs.layout.spare_slot(h):
                contrib += phi * frac
        bases.append(loads[h] - contrib)
    total = phi + base_s + sum(bases)
    per = total / (1 + len(helper_ranks))
    f_helpers = [max(0.0, (per - b)) / phi for b in bases]
    f_helpers = [min(1.0, f * boost) for f in f_helpers]
    ftot = sum(f_helpers)
    if ftot > 1.0:
        f_helpers = [f / ftot for f in f_helpers]
    r = rs.plan_slots.shape[2]
    slots = [rs.layout.spare_slot(h) for h in helper_ranks]
    slots = slots[: r - 1] + [rs.layout.home_slot(hot)] * \
        (r - min(len(slots), r - 1))
    cum, acc = [], 0.0
    for f in f_helpers[: r - 1]:
        acc = min(1.0, acc + f)
        cum.append(acc)
    cum += [1.0] * (r - len(cum))
    return np.asarray(slots[:r], np.int32), np.asarray(cum[:r], np.float32)


class LoopReshaper(MoEReshaper):
    """``MoEReshaper`` with the pre-vectorization implementation swapped in:
    the original sequential per-layer ``step`` loop plus the loop-based
    method bodies (modulo uniform float64 frac arithmetic — the original
    mixed f32/f64, see the reference functions).  Same decisions at the old
    cost; baseline for ``bench_reshaper_latency`` and the full-step
    regression tests."""

    def rank_loads_all(self) -> np.ndarray:
        return np.stack([rank_loads_loop(self, l) for l in range(self.nl)])

    def rank_loads(self, layer: int) -> np.ndarray:
        return rank_loads_loop(self, layer)

    def _current_frac(self, layer: int, expert: int) -> float:
        return current_frac_loop(self, layer, expert)

    def _waterfill(self, l: int, hot: int, helper_ranks: List[int],
                   loads: np.ndarray, boost: float = 1.0) -> None:
        slots, cum = waterfill_row_loop(self, l, hot, helper_ranks, loads,
                                        boost)
        self._plan_cache = None
        self._loads_cache = None
        self.plan_slots[l, hot] = slots
        self.plan_cum[l, hot] = cum

    def _replicas_of(self, l: int, e: int) -> List[int]:
        return [rank for (ll, rank), owner in self.spare_owner.items()
                if ll == l and owner == e]

    def step(self) -> Tuple[np.ndarray, np.ndarray, List[Migration]]:
        # verbatim pre-vectorization step: sequential per-layer sweep, loads
        # recomputed per layer, no caches
        migrations: List[Migration] = []
        if self._ema_expert is None:
            return self.plan_slots, self.plan_cum, migrations
        self._loads_cache = None
        for l in range(self.nl):
            migrations.extend(self._step_layer(l))
        return self.plan_slots.copy(), self.plan_cum.copy(), migrations

    def _step_layer(self, l: int) -> List[Migration]:
        out: List[Migration] = []
        loads = self.rank_loads(l)
        mean = max(loads.mean(), 1e-9)
        eps = float(np.sqrt(self._ema_var[l].mean())) / mean
        tau = self.adaptive.tau if self.adaptive else self.params.tau
        if self.migration_steps:
            tau = max(0.01, tau_prime(tau, 0.6, 0.4, 1.0,
                                      self.migration_steps))
        max_helpers = self.plan_slots.shape[2] - 1

        # maintain active mitigations (sequential re-waterfill)
        for (ll, hot), left in list(self.active.items()):
            if ll != l:
                continue
            s = self.layout.rank_of_expert(hot)
            helpers = self._replicas_of(l, hot)
            if not helpers:
                del self.active[(l, hot)]
                continue
            boost = 1.5 if (left > 0 and self.backlog[l, s] > 0) else 1.0
            self._waterfill(l, hot, helpers, loads, boost)
            self.active[(l, hot)] = max(0, left - 1)
            self.backlog[l, s] = max(0.0, self.backlog[l, s] - mean)

        # detect new skew
        loads = self.rank_loads(l)
        s = int(np.argmax(loads))
        if loads[s] < self.params.eta or (loads[s] - loads.min()) / mean < tau:
            return out
        cands = [e for e in range(self.cfg.moe.num_experts)
                 if self.layout.rank_of_expert(e) == s]
        hot = int(max(cands, key=lambda e: self._ema_expert[l, e]))
        if self.adaptive:
            self.adaptive.adjust(loads[s] / mean, loads.min() / mean, eps)
        self.iterations += 1

        if self.mode == "sbk":
            move = min(cands, key=lambda e: self._ema_expert[l, e])
            h = int(np.argmin(loads))
            if (l, h) not in self.spare_owner:
                spare = self.layout.spare_slot(h)
                self.spare_owner[(l, h)] = move
                out.append(Migration(l, self.layout.home_slot(move), spare))
                self._move_expert(l, move, spare)
                self.events.append(MitigationEvent(l, s, h, move, 1.0, 2,
                                                   out[-1]))
            return out

        helpers = self._replicas_of(l, hot)
        order = [int(h) for h in np.argsort(loads) if int(h) != s]
        phi = max(self._ema_expert[l, hot], 1e-9)
        for h in order:
            if len(helpers) >= max_helpers:
                break
            if h in helpers:
                continue
            if self.spare_owner.get((l, h)) not in (None, hot):
                continue
            if loads[h] >= loads[s]:
                break
            helpers.append(h)
            if (phi + sum(loads[x] for x in helpers + [s])) / \
                    (len(helpers) + 1) <= mean * (1 + tau / 2):
                break
        if not helpers:
            return out
        for h in helpers:
            if self.spare_owner.get((l, h)) != hot:
                self.spare_owner[(l, h)] = hot
                out.append(Migration(l, self.layout.home_slot(hot),
                                     self.layout.spare_slot(h)))
        has_backlog = self.backlog[l, s] > 0
        self._waterfill(l, hot, helpers, loads,
                        boost=1.5 if has_backlog else 1.0)
        self.active[(l, hot)] = self.phase1_steps if has_backlog else 0
        self.events.append(MitigationEvent(
            l, s, helpers[0], hot, float(self.plan_cum[l, hot, 0]),
            1 if has_backlog else 2, out[-1] if out else None))
        return out


def apply_migrations_np(expert_leaf: np.ndarray,
                        migrations: List[Migration]) -> np.ndarray:
    """Reference (numpy) state migration on a [L, S, ...] stacked leaf."""
    out = expert_leaf.copy()
    for m in migrations:
        out[m.layer, m.dst_slot] = out[m.layer, m.src_slot]
    return out
