"""Reshape -> MoE expert-parallel integration (Tier B, DESIGN.md §2).

The paper's abstractions mapped onto synchronous expert-parallel training:

  worker            = EP rank (device column of the "model" mesh axis)
  partition         = the logical experts whose *home slot* lives on a rank
  workload phi      = EMA of tokens routed to a rank per step (from the free
                      in-layer metrics) + overflow backlog counter
  partitioning logic= the RoutingPlan arrays (jittable step inputs)
  SBR               = split a hot expert's tokens between its home slot and a
                      replica in a helper rank's spare slot
  SBK               = move a whole expert into a helper rank's spare slot
  state migration   = copying the expert's weights (+ optimizer moments) into
                      the spare slot; cost enters tau' (§3.6.1)
  phase 1           = boosted redirect fraction while the skewed rank drains
                      its overflow backlog; phase 2 = estimator-based fraction

Slot layout interleaves one spare per rank:  rank d owns slots
[d*(epd+1), (d+1)*(epd+1)); the last one is its spare.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adaptive import TauAdjuster, tau_prime
from repro.core.skew import SkewParams


@dataclasses.dataclass(frozen=True)
class SlotLayout:
    num_experts: int
    ep_ranks: int

    @property
    def experts_per_rank(self) -> int:
        assert self.num_experts % self.ep_ranks == 0
        return self.num_experts // self.ep_ranks

    @property
    def slots_per_rank(self) -> int:
        return self.experts_per_rank + 1          # one spare per rank

    @property
    def num_slots(self) -> int:
        return self.slots_per_rank * self.ep_ranks

    def home_slot(self, e: int) -> int:
        epd = self.experts_per_rank
        return (e // epd) * self.slots_per_rank + (e % epd)

    def spare_slot(self, rank: int) -> int:
        return rank * self.slots_per_rank + self.experts_per_rank

    def rank_of_slot(self, s: int) -> int:
        return s // self.slots_per_rank

    def rank_of_expert(self, e: int) -> int:
        return e // self.experts_per_rank


@dataclasses.dataclass
class Migration:
    layer: int
    src_slot: int
    dst_slot: int


@dataclasses.dataclass
class MitigationEvent:
    layer: int
    skewed_rank: int
    helper_rank: int
    hot_expert: int
    fraction: float
    phase: int
    migration: Optional[Migration]


class MoEReshaper:
    """Host-side controller logic: observe per-step metrics, emit new plans
    + expert-state migrations between steps (the fast control path)."""

    def __init__(self, cfg: ArchConfig, n_moe_layers: int, ep_ranks: int,
                 params: SkewParams = SkewParams(eta=0.0, tau=0.25),
                 ema_beta: float = 0.8, adaptive: Optional[TauAdjuster] = None,
                 phase1_steps: int = 2, mode: str = "sbr",
                 migration_steps: float = 0.0):
        self.cfg = cfg
        self.nl = n_moe_layers
        self.layout = SlotLayout(cfg.moe.num_experts, ep_ranks)
        self.params = params                  # tau as FRACTION of mean load
        self.ema_beta = ema_beta
        self.adaptive = adaptive
        self.phase1_steps = phase1_steps
        self.mode = mode
        self.migration_steps = migration_steps
        e, r = cfg.moe.num_experts, cfg.moe.max_replicas
        self.plan_slots = np.zeros((n_moe_layers, e, r), np.int32)
        for le in range(e):
            self.plan_slots[:, le, :] = self.layout.home_slot(le)
        self.plan_cum = np.ones((n_moe_layers, e, r), np.float32)
        self._ema_expert = None               # [L, E]
        self._ema_var = None
        self.backlog = np.zeros((n_moe_layers, ep_ranks), np.float64)
        # spare-slot ownership: (layer, rank) -> expert replica hosted there
        self.spare_owner: Dict[Tuple[int, int], int] = {}
        # experts under active mitigation: (layer, expert) -> phase1 steps left
        self.active: Dict[Tuple[int, int], int] = {}
        self.events: List[MitigationEvent] = []
        self.iterations = 0

    # ------------------------------------------------------------- observe
    def observe(self, expert_counts: np.ndarray,
                dropped_per_layer: Optional[np.ndarray] = None) -> None:
        """expert_counts [L, E] tokens routed per logical expert this step."""
        x = np.asarray(expert_counts, np.float64)
        if self._ema_expert is None:
            self._ema_expert = x.copy()
            self._ema_var = np.zeros_like(x)
        else:
            d = x - self._ema_expert
            self._ema_expert = self.ema_beta * self._ema_expert + \
                (1 - self.ema_beta) * x
            self._ema_var = self.ema_beta * self._ema_var + \
                (1 - self.ema_beta) * d * d
        if dropped_per_layer is not None:
            # attribute overflow to the currently-loaded rank
            for l in range(self.nl):
                loads = self.rank_loads(l)
                self.backlog[l, int(np.argmax(loads))] += float(
                    dropped_per_layer[l])

    def rank_loads(self, layer: int) -> np.ndarray:
        """Predicted tokens/step per EP rank under the CURRENT plan."""
        loads = np.zeros(self.layout.ep_ranks)
        e = self.cfg.moe.num_experts
        for le in range(e):
            pred = self._ema_expert[layer, le]
            cum_prev = 0.0
            for r in range(self.plan_slots.shape[2]):
                cum = self.plan_cum[layer, le, r]
                frac = cum - cum_prev
                if frac > 0:
                    rank = self.layout.rank_of_slot(
                        int(self.plan_slots[layer, le, r]))
                    loads[rank] += pred * frac
                cum_prev = cum
        return loads

    # ------------------------------------------------------------ mitigate
    def _current_frac(self, layer: int, expert: int) -> float:
        """TOTAL fraction of this expert's tokens currently redirected away
        from its home slot (0 under the identity plan)."""
        home = self.layout.home_slot(expert)
        prev, redirected = 0.0, 0.0
        for slot, cum in zip(self.plan_slots[layer, expert],
                             self.plan_cum[layer, expert]):
            frac = float(cum) - prev
            prev = float(cum)
            if frac > 0 and int(slot) != home:
                redirected += frac
        return redirected

    def _set_split(self, layer: int, expert: int, helper_slot: int,
                   frac: float) -> None:
        home = self.layout.home_slot(expert)
        r = self.plan_slots.shape[2]
        self.plan_slots[layer, expert, 0] = helper_slot
        self.plan_slots[layer, expert, 1:] = home
        cum = np.ones(r, np.float32)
        cum[0] = frac
        self.plan_cum[layer, expert] = cum

    def _move_expert(self, layer: int, expert: int, dst_slot: int) -> None:
        self.plan_slots[layer, expert, :] = dst_slot
        self.plan_cum[layer, expert, :] = 1.0

    def step(self) -> Tuple[np.ndarray, np.ndarray, List[Migration]]:
        """Run detection/mitigation; returns (plan_slots, plan_cum,
        migrations to apply to params/opt state *before* the next step)."""
        migrations: List[Migration] = []
        if self._ema_expert is None:
            return self.plan_slots, self.plan_cum, migrations
        for l in range(self.nl):
            migrations.extend(self._step_layer(l))
        return self.plan_slots.copy(), self.plan_cum.copy(), migrations

    def _replicas_of(self, l: int, e: int) -> List[int]:
        """Spare-slot ranks currently hosting a replica of expert e."""
        return [rank for (ll, rank), owner in self.spare_owner.items()
                if ll == l and owner == e]

    def _waterfill(self, l: int, hot: int, helper_ranks: List[int],
                   loads: np.ndarray, boost: float = 1.0) -> None:
        """Split the hot expert across its home rank + helper spares so all
        participating ranks approach the common level (§3.6.2 extended to
        SBR fractions).  ``boost`` > 1 over-redirects (phase-1 catch-up)."""
        s = self.layout.rank_of_expert(hot)
        phi = max(self._ema_expert[l, hot], 1e-9)
        base_s = loads[s] - phi * (1.0 - self._current_frac(l, hot))
        # subtract this expert's replica contribution from each helper's base
        bases = []
        cur_slots = list(self.plan_slots[l, hot])
        cur_cum = list(self.plan_cum[l, hot])
        for h in helper_ranks:
            contrib = 0.0
            prev = 0.0
            for slot, cum in zip(cur_slots, cur_cum):
                frac = cum - prev
                prev = cum
                if frac > 0 and self.layout.rank_of_slot(int(slot)) == h and \
                        int(slot) == self.layout.spare_slot(h):
                    contrib += phi * frac
            bases.append(loads[h] - contrib)
        total = phi + base_s + sum(bases)
        per = total / (1 + len(helper_ranks))
        f_helpers = [max(0.0, (per - b)) / phi for b in bases]
        f_helpers = [min(1.0, f * boost) for f in f_helpers]
        ftot = sum(f_helpers)
        if ftot > 1.0:
            f_helpers = [f / ftot for f in f_helpers]
            ftot = 1.0
        # plan row: [spare(h1), spare(h2), ..., home, home, ...]
        r = self.plan_slots.shape[2]
        slots = [self.layout.spare_slot(h) for h in helper_ranks]
        slots = slots[: r - 1] + [self.layout.home_slot(hot)] * \
            (r - min(len(slots), r - 1))
        cum, acc = [], 0.0
        for f in f_helpers[: r - 1]:
            acc = min(1.0, acc + f)
            cum.append(acc)
        cum += [1.0] * (r - len(cum))
        self.plan_slots[l, hot] = np.asarray(slots[:r], np.int32)
        self.plan_cum[l, hot] = np.asarray(cum[:r], np.float32)

    def _step_layer(self, l: int) -> List[Migration]:
        out: List[Migration] = []
        loads = self.rank_loads(l)
        mean = max(loads.mean(), 1e-9)
        eps = float(np.sqrt(self._ema_var[l].mean())) / mean
        tau = self.adaptive.tau if self.adaptive else self.params.tau
        if self.migration_steps:
            tau = max(0.01, tau_prime(tau, 0.6, 0.4, 1.0,
                                      self.migration_steps))
        max_helpers = self.plan_slots.shape[2] - 1

        # ---- maintain active mitigations: re-waterfill with a stable
        # helper set; phase-1 boost while the backlog drains (two phases)
        for (ll, hot), left in list(self.active.items()):
            if ll != l:
                continue
            s = self.layout.rank_of_expert(hot)
            helpers = self._replicas_of(l, hot)
            if not helpers:
                del self.active[(l, hot)]
                continue
            boost = 1.5 if (left > 0 and self.backlog[l, s] > 0) else 1.0
            self._waterfill(l, hot, helpers, loads, boost)
            self.active[(l, hot)] = max(0, left - 1)
            self.backlog[l, s] = max(0.0, self.backlog[l, s] - mean)

        # ---- detect new skew (eq 3.1/3.2 at rank granularity)
        loads = self.rank_loads(l)
        s = int(np.argmax(loads))
        if loads[s] < self.params.eta or (loads[s] - loads.min()) / mean < tau:
            return out
        cands = [e for e in range(self.cfg.moe.num_experts)
                 if self.layout.rank_of_expert(e) == s]
        hot = int(max(cands, key=lambda e: self._ema_expert[l, e]))
        if self.adaptive:
            self.adaptive.adjust(loads[s] / mean, loads.min() / mean, eps)
        self.iterations += 1

        if self.mode == "sbk":
            # move the smallest expert worth ~the gap (cannot split the hot
            # key — the Flux-style limitation the paper contrasts with)
            move = min(cands, key=lambda e: self._ema_expert[l, e])
            h = int(np.argmin(loads))
            if (l, h) not in self.spare_owner:
                spare = self.layout.spare_slot(h)
                self.spare_owner[(l, h)] = move
                out.append(Migration(l, self.layout.home_slot(move), spare))
                self._move_expert(l, move, spare)
                self.events.append(MitigationEvent(l, s, h, move, 1.0, 2,
                                                   out[-1]))
            return out

        # ---- SBR: (re)build the helper set for the hot expert — reuse its
        # existing replicas, extend with least-loaded ranks w/ free spares
        helpers = self._replicas_of(l, hot)
        order = [int(h) for h in np.argsort(loads) if int(h) != s]
        phi = max(self._ema_expert[l, hot], 1e-9)
        for h in order:
            if len(helpers) >= max_helpers:
                break
            if h in helpers:
                continue
            if self.spare_owner.get((l, h)) not in (None, hot):
                continue                      # spare already hosts another
            # does adding this helper reduce the common level? (chi logic)
            if loads[h] >= loads[s]:
                break
            helpers.append(h)
            if (phi + sum(loads[x] for x in helpers + [s])) / \
                    (len(helpers) + 1) <= mean * (1 + tau / 2):
                break
        if not helpers:
            return out
        for h in helpers:
            if self.spare_owner.get((l, h)) != hot:
                self.spare_owner[(l, h)] = hot
                out.append(Migration(l, self.layout.home_slot(hot),
                                     self.layout.spare_slot(h)))
        has_backlog = self.backlog[l, s] > 0
        self._waterfill(l, hot, helpers, loads,
                        boost=1.5 if has_backlog else 1.0)
        self.active[(l, hot)] = self.phase1_steps if has_backlog else 0
        self.events.append(MitigationEvent(
            l, s, helpers[0], hot, float(self.plan_cum[l, hot, 0]),
            1 if has_backlog else 2, out[-1] if out else None))
        return out


def apply_migrations_np(expert_leaf: np.ndarray,
                        migrations: List[Migration]) -> np.ndarray:
    """Reference (numpy) state migration on a [L, S, ...] stacked leaf."""
    out = expert_leaf.copy()
    for m in migrations:
        out[m.layer, m.dst_slot] = out[m.layer, m.src_slot]
    return out
