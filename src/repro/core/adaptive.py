"""Adaptive threshold tuning (paper Algorithm 1, §3.4.3.2 and §3.6.1).

    if phi_S - phi_H >= tau and eps > eps_u:   tau <- increase(tau)
    elif phi_S - phi_H < tau and eps < eps_l:  tau <- phi_S - phi_H  (start now)
    else:                                      tau unchanged

High state-migration time correction (§3.6.1): start mitigation early at
    tau' = tau - (f_hat_S - f_hat_H) * t * M
so the migration *ends* when the gap reaches tau.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class TauAdjuster:
    eps_l: float
    eps_u: float
    tau: float
    increase_by: float = 50.0
    min_tau: float = 1.0

    def adjust(self, phi_s: float, phi_h: float, eps: float) -> float:
        gap = phi_s - phi_h
        if gap >= self.tau and eps > self.eps_u:
            # sample too small for a good estimate -> wait longer next time
            self.tau = self.tau + self.increase_by
        elif gap < self.tau and eps < self.eps_l:
            # estimate already good -> don't wait, mitigate at current gap
            self.tau = max(self.min_tau, gap)
        return self.tau


def tau_prime(tau_n: float, f_hat_s: float, f_hat_h: float,
              tuples_per_sec: float, migration_secs: float) -> float:
    """§3.6.1: detection threshold corrected for state-migration time M."""
    return tau_n - (f_hat_s - f_hat_h) * tuples_per_sec * migration_secs
