"""Jobs: the unit the engine schedules, expressed as Maestro regions.

Everything the runtime does — a train step on either control path, a serve
prefill chunk, a decode batch, a checkpoint — is described as a small
region-structured workflow (paper Ch.4) whose operator costs come from the
engine's :class:`~repro.core.estimator.CostBook` (measured online, not
modeled).  The engine then applies the result-aware objectives from
``core.scheduler`` to the workflow:

* ``first_response_time`` — time to the first tuple out of the sink
  (first microbatch metrics for training, first emitted token for serving);
* ``completion_time`` — time to drain every region.

The objectives split by who is waiting: **training decisions minimize
completion time** (nobody reads anything until the whole step lands), while
**serving decisions minimize first-response time** (a user is waiting on the
first token) — weighted by priority class in the multi-pool case.  The
decisions made this way today:

* **train step path** (fused vs granulated): the granulated workflow puts
  every microbatch in its own region with a pipelined edge from the first
  microbatch to the control sink — its FRT is one microbatch, the Amber
  control latency.  The fused workflow is a single region — minimal
  completion time, but the control sink waits for the whole step.
* **serve tick composition** (decode-only vs prefill): prefill is a
  blocking region upstream of decode — admitting a prefill chunk delays
  the first token out of the decode region by the full prefill cost, which
  is exactly why short decode batches preempt long prefills under min-FRT.
* **multi-pool arbitration** (which slot pool ticks next): every pool
  offers its candidate ticks as :class:`TickCandidate` descriptors; the
  engine scores each candidate's ``serve_tick_workflow`` FRT — with the
  pool's *own* measured per-token EMA as the cost term — divided by the
  summed priority-class weight of the requests the tick advances, subject
  to the per-class aging bound (no admitted prefill sits out more than its
  class's ``max_defer`` scheduled ticks).

Invariants the differential harness (tests/test_serve_differential.py)
enforces on everything scheduled from here: greedy serve outputs are
**bit-identical** to the static ``generate_static`` oracle under *every*
tick ordering these decisions can produce (scheduling reorders work, never
changes results — per-slot state is isolated and joins are reset-masked
in-jit), across compact × speculative × multi-pool × priority sweeps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.regions import Op, Workflow


@dataclasses.dataclass(frozen=True)
class Job:
    """One schedulable unit of runtime work.

    ``kind`` doubles as the CostBook key, so every job the engine runs
    refines the cost model used to schedule the next one."""
    kind: str                 # train_step_fused | train_step_granulated |
    #                           serve_prefill | serve_decode |
    #                           serve_spec_decode | serve_seed | checkpoint
    tokens: int = 0           # data-plane size (tokens processed)
    meta: Optional[dict] = None


# ------------------------------------------------------------------ training

def train_step_workflow(path: str, n_mb: int, t_mb: float,
                        t_apply: float) -> Workflow:
    """The training step as a region workflow.

    granulated: mb_0 -> mb_1 -> ... -> apply with *blocking* edges (each
    microbatch is its own region; region boundaries are the Amber control
    points) and a pipelined edge mb_0 -> control (the first metrics/poll
    response leaves after one microbatch).
    fused: one region {step} -> control; nothing escapes until the whole
    scanned step completes.
    """
    wf = Workflow()
    if path == "fused":
        wf.add_op(Op("step", "ml", cost_per_tuple=n_mb * t_mb + t_apply,
                     source_cardinality=1.0))
        wf.add_op(Op("control", "sink", cost_per_tuple=0.0))
        wf.add_edge("step", "control")
        return wf
    assert path == "granulated", path
    for i in range(n_mb):
        wf.add_op(Op(f"mb_{i}", "ml", cost_per_tuple=t_mb,
                     source_cardinality=1.0 if i == 0 else 0.0))
    wf.add_op(Op("apply", "ml", cost_per_tuple=t_apply))
    wf.add_op(Op("control", "sink", cost_per_tuple=0.0))
    for i in range(n_mb - 1):
        wf.add_edge(f"mb_{i}", f"mb_{i + 1}", blocking=True)
    wf.add_edge(f"mb_{n_mb - 1}", "apply", blocking=True)
    wf.add_edge("mb_0", "control")
    return wf


# ------------------------------------------------- MoE dispatch kernel choice

def dispatch_kind(impl: str, tokens: int) -> str:
    """CostBook key for a step executed with one MoE dispatch impl.  Keyed
    per token count so the choice is made *per shape*: the fused kernel's
    advantage depends on T*k (the rank/scatter pipeline is linear in it,
    the argsort is not), so one global EMA would wash shapes together."""
    return f"moe_dispatch_{impl}:t{tokens}"


def moe_dispatch_workflow(impl: str, tokens: int, t_total: float) -> Workflow:
    """The MoE dispatch/combine primitive as a region workflow.

    ``xla`` is the argsort pipeline: rank (sort+searchsorted), bucketed
    scatter, the per-slot expert matmuls, and the gather/combine each run
    as their own launch, so each is its own blocking region.  ``fused``
    collapses rank+mask+scatter into one kernel region and the weighted
    gather into another.  Region costs split the *measured* total for the
    impl (the CostBook EMA), so scoring the two candidates under
    ``completion_time`` — exactly how ``choose_step_path`` scores step
    workflows — picks the cheaper kernel for this shape on this machine.
    """
    if impl == "fused":
        stages = (("dispatch_kernel", 0.3), ("experts", 0.4),
                  ("combine_kernel", 0.3))
    else:
        stages = (("rank_sort", 0.2), ("scatter", 0.2), ("experts", 0.4),
                  ("gather_combine", 0.2))
    wf = Workflow()
    wf.add_op(Op("tokens", "scan", cost_per_tuple=0.0,
                 source_cardinality=1.0))
    prev = "tokens"
    for name, share in stages:
        wf.add_op(Op(name, "ml", cost_per_tuple=share * t_total))
        wf.add_edge(prev, name, blocking=(prev != "tokens"))
        prev = name
    wf.add_op(Op("out", "sink", cost_per_tuple=0.0))
    wf.add_edge(prev, "out")
    return wf


# ------------------------------------------------------------------- serving

def serve_tick_workflow(decode_slots: int, decode_chunk: int,
                        prefill_tokens: int, t_token: float,
                        t_dispatch: float = 0.0) -> Workflow:
    """One serve tick as a region workflow.

    ``prefill_tokens = 0`` models a decode-only tick: the decode region is
    the sink's region and only pays its pipeline fill (one chunk of
    ``decode_chunk`` positions).  With pending prefill work the prefill op
    sits behind a *blocking* edge into decode — the whole prefill chunk is
    paid before the first token streams out.  first_response_time on these
    two candidates is the admission/composition decision.
    """
    wf = Workflow()
    wf.add_op(Op("requests", "scan", cost_per_tuple=0.0,
                 source_cardinality=float(max(decode_slots, 1))))
    wf.add_op(Op("decode", "ml",
                 cost_per_tuple=t_token * decode_chunk + t_dispatch))
    wf.add_op(Op("stream_out", "sink", cost_per_tuple=0.0))
    wf.add_edge("requests", "decode")
    wf.add_edge("decode", "stream_out")
    if prefill_tokens > 0:
        wf.add_op(Op("pending", "scan", cost_per_tuple=0.0,
                     source_cardinality=float(prefill_tokens)))
        wf.add_op(Op("prefill", "ml", cost_per_tuple=t_token))
        wf.add_edge("pending", "prefill")
        wf.add_edge("prefill", "decode", blocking=True)
    return wf


def pool_kind(kind: str, pool_id: int) -> str:
    """CostBook key for a serve tick kind on one slot pool.  Tick jobs are
    recorded under BOTH the global kind and this pool-scoped kind: the
    global EMA bootstraps pools that have not run yet, the per-pool EMA is
    what the multi-pool arbitration scores — it is the parallelism term of
    the weighted-FRT objective (a pool on faster hardware shows a lower
    measured per-token time and wins more ticks)."""
    return f"{kind}:p{pool_id}"


@dataclasses.dataclass
class TickCandidate:
    """One schedulable tick a slot pool offers the engine this round.

    The serving engine builds one candidate per (pool, composition) pair
    that has work — a decode candidate when any slot holds a pending
    sampled token, a prefill candidate when any slot still consumes prompt
    — and ``Engine.choose_serve_job`` arbitrates across all of them.
    ``weight`` is the summed priority-class weight of the requests whose
    first response the candidate advances; ``aged`` marks a candidate
    containing a request past its class's ``max_defer`` bound, which
    removes every non-aged candidate from consideration."""
    pool_id: int
    mode: str                  # "decode" | "prefill"
    n_dec: int = 0             # decode-state participants in the pool
    n_pre: int = 0             # prefilling participants in the pool
    pre_toks: int = 0          # pending prompt tokens behind the tick
    chunk: int = 1             # tick length this candidate would run
    weight: float = 1.0        # summed class weight of advanced requests
    aged: bool = False         # a participant hit its class aging bound
    overdue: int = 0           # ticks past the tightest violated bound
    spec_len: int = 0          # >1: the speculative arm is offered
    arms: tuple = ()           # proposer arms offered ("ngram", "draft", ...)
    # placement terms (0.0 without ServeEngine placements, which reduces
    # the arbitration score to exactly the historical weighted FRT):
    load: float = 0.0          # busy fraction of the pool's device group
    xfer: float = 0.0          # pending migration cost (s) headed at the
    #                            pool — priced from the serve_migrate EMA


def accept_kind(pool_id: int, arm: str = "ngram") -> str:
    """CostBook key for a slot pool's speculative-decode acceptance-rate
    EMA, per proposer arm.  Keyed per pool because pools serve different
    traffic (acceptance is a property of the *workload* flowing through a
    pool, not of the machine) and per arm because proposers fail
    differently — the n-gram table collapses on non-repetitive text where
    a distilled draft model keeps agreeing."""
    return f"serve_accept:{arm}:p{pool_id}"


def spec_kind(arm: str) -> str:
    """CostBook key for the speculative tick run with one proposer arm.
    Per-arm runtimes differ structurally — the draft arm pays the draft
    model's propose scan and per-step cache threading inside the same
    dispatch — so each arm carries its own EMA; the unsuffixed
    ``serve_spec_decode`` aggregate is still recorded as the bootstrap
    fallback for tick-composition pricing."""
    return f"serve_spec_decode:{arm}"


def layout_kind(compact: bool, pool_id: int) -> str:
    """CostBook key for a decode tick's batch layout on one pool: compact
    (participants gathered into a power-of-two batch before the vmap) vs
    full (all slots run, sat-out lanes burn FLOPs).  Recorded only on ticks
    where compaction was *eligible* (>= half the pool sitting out), so the
    two EMAs compare the same occupancy regime and
    ``Engine.choose_compact`` can flip the layout from measurement."""
    return f"serve_tick_{'compact' if compact else 'full'}:p{pool_id}"


def knob_kind(name: str, value) -> str:
    """CostBook key for one (engine knob, arm value) pair — the autotune
    meta-decision's measurement substrate.  Each arm of a tuned knob
    (``spec_len=4``, ``prefill_chunk=16``, ...) accumulates its own
    windowed cost-per-token EMA while it is the live setting, so
    ``Engine.choose_knob`` scores knob values the same way every other
    Maestro decision scores its arms: from measured behavior, not
    assumption.  The value is embedded in the key verbatim (knob values
    are small ints/floats), so distinct arms can never alias."""
    return f"autotune:{name}={value}"


def serve_decode_workflow(arm: str, decode_slots: int, chunk: int,
                          t_token: float, accept: float = 0.0) -> Workflow:
    """One decode-composition tick as a region workflow, per arm.

    ``plain``: the decode op runs ``chunk`` scan steps, each sampling (and
    therefore committing) one token per slot — its selectivity is ``chunk``,
    so the sink's cardinality is exactly the committed-token count.

    ``spec``: one workflow shape for the whole proposer family — the draft
    op produces the chain (n-gram table lookup or draft-model decode; either
    way its cost rides inside the measured verify dispatch, which is why the
    engine prices each arm with its own ``spec_kind(arm)`` runtime EMA and
    ``accept_kind(pool_id, arm)`` acceptance EMA), the verify op pays the
    full ``chunk`` scan steps (selectivity ``chunk``: every verified
    position is a candidate token), and the commit op keeps only the
    accepted prefix:
    its *selectivity* is ``(1 + accept·(chunk-1)) / chunk``, so the sink's
    cardinality is the expected committed-token count.  Region time is paid
    on the verify op regardless of acceptance — exactly the speculative
    gamble.  The engine scores both arms under ``completion_time``
    normalized by expected commits (``Engine._choose_decode_arm``)."""
    wf = Workflow()
    wf.add_op(Op("requests", "scan", cost_per_tuple=0.0,
                 source_cardinality=float(max(decode_slots, 1))))
    if arm == "plain":
        wf.add_op(Op("decode", "ml", cost_per_tuple=t_token * chunk,
                     selectivity=float(chunk)))
        wf.add_op(Op("stream_out", "sink", cost_per_tuple=0.0))
        wf.add_edge("requests", "decode")
        wf.add_edge("decode", "stream_out")
        return wf
    assert arm.startswith("spec"), arm
    committed = 1.0 + accept * max(chunk - 1, 0)
    wf.add_op(Op("draft", "ml", cost_per_tuple=0.0))
    wf.add_op(Op("verify", "ml", cost_per_tuple=t_token * chunk,
                 selectivity=float(chunk)))
    wf.add_op(Op("commit", "ml", cost_per_tuple=0.0,
                 selectivity=committed / max(chunk, 1)))
    wf.add_op(Op("stream_out", "sink", cost_per_tuple=0.0))
    wf.add_edge("requests", "draft")
    wf.add_edge("draft", "verify")
    wf.add_edge("verify", "commit")
    wf.add_edge("commit", "stream_out")
    return wf


def prefill_workflow(prompt_tokens: int, t_token: float) -> Workflow:
    """Admission by recomputation: prefill the WHOLE prompt from token 0.
    The prefill region sits behind a blocking edge into decode — every
    prompt token is paid before the first response token can stream out,
    so the workflow's FRT is ``prompt_tokens * t_token`` plus the decode
    pipeline fill.  This is the baseline ``Engine.choose_prefix_admission``
    prices the cached alternative against."""
    wf = Workflow()
    wf.add_op(Op("prompt", "scan", cost_per_tuple=0.0,
                 source_cardinality=float(max(prompt_tokens, 1))))
    wf.add_op(Op("prefill", "ml", cost_per_tuple=t_token))
    wf.add_op(Op("decode", "ml", cost_per_tuple=t_token))
    wf.add_op(Op("stream_out", "sink", cost_per_tuple=0.0))
    wf.add_edge("prompt", "prefill")
    wf.add_edge("prefill", "decode", blocking=True)
    wf.add_edge("decode", "stream_out")
    return wf


def prefix_seed_workflow(cached_tokens: int, suffix_tokens: int,
                        t_seed: float, t_token: float) -> Workflow:
    """Admission by reuse: copy a cached prefix snapshot into the joining
    slot (one batched row write — ``t_seed``, a *constant* cost set by the
    cache-row size, not by how many tokens the snapshot encodes), then
    prefill only the unshared suffix.  The seed-copy region is the
    materialized intermediate state being read back — Whiz's reuse edge as
    a region — and blocks the suffix prefill exactly as prefill blocks
    decode.  FRT therefore compares ``t_seed + suffix·t_token`` against
    recomputation's ``(cached+suffix)·t_token``: reuse wins whenever the
    copy is cheaper than recomputing the cached tokens, which is the
    result-aware decision in one inequality."""
    wf = Workflow()
    wf.add_op(Op("snapshot", "scan", cost_per_tuple=t_seed,
                 source_cardinality=1.0))
    wf.add_op(Op("seed_copy", "ml", cost_per_tuple=0.0,
                 selectivity=float(max(suffix_tokens, 1))))
    wf.add_op(Op("prefill_suffix", "ml", cost_per_tuple=t_token))
    wf.add_op(Op("decode", "ml", cost_per_tuple=t_token))
    wf.add_op(Op("stream_out", "sink", cost_per_tuple=0.0))
    wf.add_edge("snapshot", "seed_copy")
    wf.add_edge("seed_copy", "prefill_suffix", blocking=True)
    wf.add_edge("prefill_suffix", "decode", blocking=True)
    wf.add_edge("decode", "stream_out")
    return wf


def checkpoint_workflow(t_save: float) -> Workflow:
    """Legacy blocking checkpoint: snapshot AND persist as one blocking
    region between steps (the §2.6 barrier paid in full).  Kept as the
    measured baseline the async split is benchmarked against
    (``LoopConfig(ckpt_async=False)``)."""
    wf = Workflow()
    wf.add_op(Op("snapshot", "ml", cost_per_tuple=t_save,
                 source_cardinality=1.0))
    wf.add_op(Op("durable", "sink", cost_per_tuple=0.0))
    wf.add_edge("snapshot", "durable", blocking=True)
    return wf


def snapshot_workflow(t_snap: float) -> Workflow:
    """The blocking half of the async checkpoint: one device→host copy —
    a single device sync, no I/O.  The blocking edge into the barrier sink
    is the only stall the training loop pays per checkpoint; everything
    downstream of the captured host payload rides ``persist_workflow``."""
    wf = Workflow()
    wf.add_op(Op("snapshot", "ml", cost_per_tuple=t_snap,
                 source_cardinality=1.0))
    wf.add_op(Op("barrier", "sink", cost_per_tuple=0.0))
    wf.add_edge("snapshot", "barrier", blocking=True)
    return wf


def persist_workflow(t_persist: float) -> Workflow:
    """The pipelined half: host→disk serialization + fsync + atomic
    publish + manifest ack, on the checkpointer's worker thread.  The
    PIPELINED edge into the durable sink is the point of the split — the
    persist region overlaps the next train step's regions, and the engine
    prices the overlap from the measured ``ckpt_persist`` EMA (observed
    from the worker thread at completion).  The durable-log barrier rides
    the ack at the end of the region: recovery only restores acknowledged
    checkpoints, so a crash mid-persist replays from the previous one."""
    wf = Workflow()
    wf.add_op(Op("persist", "ml", cost_per_tuple=t_persist,
                 source_cardinality=1.0))
    wf.add_op(Op("durable", "sink", cost_per_tuple=0.0))
    wf.add_edge("persist", "durable")
    return wf


COST_DEFAULTS: Dict[str, float] = {
    # bootstrap priors (seconds) used until the CostBook has measurements;
    # relative order is what matters: fused step < granulated step,
    # decode tick < prefill chunk.
    "train_step_fused": 0.05,
    "train_step_granulated": 0.10,
    "serve_decode": 0.01,
    "serve_spec_decode": 0.01,
    # per-proposer-arm verify-tick priors: the draft arm carries the draft
    # model's propose/threading cost, so its prior sits slightly above the
    # table-lookup arm's
    "serve_spec_decode:ngram": 0.01,
    "serve_spec_decode:draft": 0.012,
    "serve_prefill": 0.05,
    # one batched cache-row copy (prefix-cache seeding); cheaper than a
    # prefill chunk by construction — the bootstrap must favor exploring
    # the seed arm so its real cost gets measured
    "serve_seed": 0.002,
    # one batched cross-pool slot migration (gather + device_put + scatter);
    # prior sits above the same-device seed write — it pays a transfer
    "serve_migrate": 0.004,
    "checkpoint": 0.50,
    # async checkpoint split: the snapshot region (one device→host sync)
    # is an order cheaper than the persist region (serialize+fsync), which
    # is why persisting on the worker thread removes most of the stall
    "ckpt_snapshot": 0.05,
    "ckpt_persist": 0.45,
}
