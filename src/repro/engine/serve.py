"""ServeEngine: continuous batching under the Amber control plane.

Serving runs as engine jobs over a fixed pool of *slots*, each slot holding
one request's KV/SSM cache at its own sequence position (the per-slot state
is the old ``BatchedServer``'s batch row, promoted to join/evict at tick
boundaries).  A **tick** is one jitted dispatch that advances every
participating slot by ``chunk`` positions:

* a *prefill* slot consumes up to ``chunk`` prompt tokens (chunked batched
  prefill — one dispatch per chunk instead of the old one dispatch per
  token);
* a *decode* slot feeds its pending sampled token and keeps sampling
  in-jit, emitting up to ``chunk`` new tokens per dispatch;
* a slot whose prompt ends mid-tick transitions prefill -> decode inside
  the same dispatch.

Between ticks the engine polls the controller mailbox, so Pause / Inspect /
Update land at tick granularity exactly like the training loop's microbatch
control points, and while paused the engine keeps answering Inspect —
serving gets §2.4.4 semantics for free.  Tick *composition* (decode-only vs
prefill) is a Maestro min-FRT choice over the two candidate region
workflows (``jobs.serve_tick_workflow``): short decode ticks preempt long
prefills until the aging bound forces prefill progress.

**Speculative in-tick decoding** (``spec_decode=True``): a *proposer*
(:class:`Proposer`) drafts up to ``cfg.serve.spec_len`` tokens per decode
tick; the target model verifies the whole draft chain in the same
chunk-scan dispatch: a carried ``valid`` mask commits the longest accepted
prefix and masks every non-positional state update (recurrent caches, pos,
table) past the first mismatch, which keeps *all* cache families correct
(recurrent and conv state cannot be position-rewound the way KV rows can)
and makes greedy outputs bit-identical to plain decode by construction — an
accepted draft IS the token greedy decode would have fed.  Two proposers
share that contract:

* ``ngram`` — a per-slot n-gram suffix-hash table, int32 arrays living in
  the donated slot pool and updated in-jit from every token the slot
  streams (prompt and generated alike), so proposing costs no host
  round-trip.  Strong on repetitive streams, collapses on random text.
* ``draft`` — a second, much smaller parameter set (``engine.draft``:
  either a truncated-layer *self*-draft sliced from the serve model, or an
  independently-specified/distilled small config) that greedily decodes
  ``spec_len - 1`` steps ahead inside the same dispatch.  Its per-slot
  cache rows live in the donated pool (``pool["draft"]``) — reset-masked on
  join, snapshotted/seeded by the prefix cache with the rest of the row —
  and are advanced by every committed token on *every* arm (prefill, plain
  decode, and verify alike), so the draft state is always exactly the
  committed stream.  The propose scan runs on throwaway copies; a wrong,
  stale, or hot-swapped draft (``update(draft_params=...)``) can only
  lower acceptance, never change outputs.

Which arm a decode tick runs — plain, ``spec:ngram``, or ``spec:draft`` —
is an engine decision from measured per-arm acceptance-rate and runtime
EMAs (``Engine._choose_decode_arm``); speculative arms are host-gated to
all-greedy participants because verifying sampled (temperature > 0)
continuations greedily would change their distribution.

**Multi-pool, priority-aware serving**: a ServeEngine owns ``pools`` slot
pools (each a :class:`SlotPool` with its own donated cache pool; the tick
jits are shared across pools via the memoized ``build_slot_tick``), and
requests carry a ``priority`` naming one of ``cfg.serve.classes``.  Each
scheduling round, every pool with work offers its candidate ticks
(``jobs.TickCandidate``) and ``Engine.choose_serve_job`` picks ONE
(pool, composition) under the weighted-FRT objective — candidate FRT costed
with the pool's own measured per-token EMA, divided by the summed class
weight of the requests the tick advances — subject to per-class aging
bounds: an admitted prefill that has sat out ``max_defer`` scheduled ticks
forces its pool's prefill candidate, whatever the weights say.  With one
pool and the default single-class table the engine takes the original
single-pool decision path (``Engine.choose_serve_tick``) unchanged.

**Cross-request prefix cache + result cache** (``prefix_cache=True``): the
engine treats the KV/SSM state of every prefix it has prefilled as a
first-class, reusable artifact (``engine.prefix_cache``).  At prefill tick
boundaries a still-prefilling slot's pool row — every cache leaf plus its
n-gram table, at the frozen position — is snapshotted into a radix tree
keyed by the consumed token prefix; a joining request that shares a cached
prefix *seeds* its slot from the snapshot with one jitted batched row write
(the same no-eager-scatter discipline as the reset-mask join) and prefills
only the unshared suffix, and an exact-repeat greedy request is answered
straight from the result cache without touching a slot.  Reuse is a
measured Maestro decision, not a heuristic: ``Engine.choose_prefix_admission``
prices ``jobs.prefix_seed_workflow`` (copy + suffix) against
``jobs.prefill_workflow`` (recompute) with per-pool CostBook EMAs.  Seeding
and result hits are host-gated to greedy requests, like the speculative
arm: a sampled request's key stream advances once per scan step, so
skipping prefill steps would change which draws produce its tokens.
Seeded state is bit-identical to recomputation by construction — the tick
consumes tokens one ``lm.decode_step`` at a time, so the state after P
tokens does not depend on chunking or on which slot ran them.

**Device-placed pools + elastic scale** (``placements={pool: mesh}``): a
slot pool may own a real device group — its params are committed to the
pool's :func:`repro.runtime.sharding.pool_mesh` (replicated at the default
``serve.pool_tp=1``, tensor-parallel above it) and its donated pool state
lives there under :func:`pool_specs` — so decode ticks for pools on
disjoint devices overlap: the scheduling round still picks ONE arbitration
winner, but with ``serve.parallel_ticks`` the engine co-dispatches plain
decode ticks for the other placed pools in the same round (async dispatch;
each pool's measured time is its elapsed-from-round-start, so the EMAs see
the overlapped reality).  Placement feeds back into the decisions:
candidate ticks carry a device-group *load* term and a pending-migration
*transfer* term (``scheduler.placement_adjusted_frt``), and admission onto
placed pools is an engine decision over occupancy-inflated per-token EMAs
(``Engine.choose_admission_pool``).  Pools are elastic under load:
``add_pool()`` joins a new (optionally placed) pool, ``drain_pool()``
stops admission and live-migrates the in-flight slots — full pool rows,
positions and PRNG keys, moved by a jitted gather → ``device_put`` →
jitted batched scatter path (``_migrate_slots``) — then retires the empty
pool.  A slot's row + position + key fully determine its continuation, so
greedy outputs are bit-identical across any migration, and zero requests
drop.

Scheduling objective: serving minimizes (weighted) **first-response time**
— a user is waiting on the first token — where training minimizes
completion time; see ``core.scheduler`` for both objectives.

Invariants the differential harness (tests/test_serve_differential.py)
enforces on this module:

* **Greedy bit-identicality** — greedy outputs equal the static
  ``BatchedServer.generate_static`` oracle, token for token, under every
  tick ordering, pool count, priority mix, compact gather, and speculative
  arm the scheduler can produce.  Scheduling reorders work; it must never
  change results.
* **Reset-mask join** — a request joins a slot by flagging the row for
  in-jit zeroing (the ``reset`` mask) instead of eager scatters; no stale
  cache, n-gram-table, or position state may leak between consecutive
  occupants of a slot, in any pool.

The per-slot compute is ``jax.vmap`` over the stock ``lm.decode_step`` —
per-slot positions come from batching the *function*, not from touching the
block-level cache layouts — and greedy outputs are bit-identical to the old
token-by-token server (the regression oracle in the tests).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import messages as M
from repro.core.breakpoints import GlobalCountBreakpoint, LocalBreakpoint
from repro.engine.engine import Engine
from repro.engine.jobs import (COST_DEFAULTS, Job, TickCandidate,
                               layout_kind, pool_kind, spec_kind)
from repro.engine.prefix_cache import PrefixAnalyzer, PrefixCache, to_host
from repro.models import lm
from repro.runtime.sharding import (axis_size, named, param_specs, pool_mesh,
                                    pool_specs)


def sample_traced(logits, key, temp):
    """In-jit sampler with a *traced* temperature: greedy at temp<=0,
    categorical otherwise (both branches computed; jnp.where selects)."""
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    t = jnp.maximum(temp, 1e-6)
    samp = jax.random.categorical(key, logits / t).astype(jnp.int32)
    return jnp.where(temp > 0, samp, greedy)


# xxhash/murmur-style odd multipliers, one per n-gram context position
_NG_MULTS = (0x9E3779B1, 0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F)

# cache families whose writes are position-addressed: a rejected speculative
# step's write lands at (or rings onto) the index of the first uncommitted
# position, which every read masks out (attention masks keys past ``pos``)
# and which the next *accepted* token overwrites before it is ever read —
# so these leaves need no valid-mask in the speculative scan.  Recurrent
# and rolling-window state (rwkv's mixed states, mamba's conv window and
# SSM state) mutates in place every step and MUST stay masked: it cannot
# be position-rewound.
_POSITIONAL_CACHE_TYPES = ("attn", "local", "moe", "shared_attn", "dec")


class Proposer:
    """One speculative-proposer arm: the source of the draft chain the
    target verifies.

    The contract every implementation shares (and the differential harness
    enforces): ``build(cfg, draft_cfg, ng_hash, push)`` returns a traced
    ``propose(dparams, draft_caches, ng, ctx, pos, toks) -> [L] tokens``
    whose output chain starts with ``toks[0]`` (the pending committed
    token) followed by ``L-1`` proposals, and which mutates **no persistent
    state** — any state the proposal consumes is carried through the scan
    as throwaway copies.  The verify scan then re-feeds every token through
    the persistent per-slot state under the valid-mask/freeze discipline,
    so a proposer can only affect *acceptance*: correctness is the target
    model's argmax, whatever was proposed."""

    name: str = ""

    @staticmethod
    def build(cfg, draft_cfg, ng_hash, push):
        raise NotImplementedError


class NgramProposer(Proposer):
    """Successor lookups from the slot's in-pool n-gram suffix table."""

    name = "ngram"

    @staticmethod
    def build(cfg, draft_cfg, ng_hash, push):
        def propose(dparams, draft, ng, ctx, pos, toks):
            L = toks.shape[0]

            def step(carry, _):
                win, tok = carry
                win = push(win, tok)
                nxt = ng[ng_hash(win)]
                return (win, nxt), nxt

            _, drafts = jax.lax.scan(step, (ctx, toks[0]), None,
                                     length=L - 1)
            return jnp.concatenate([toks[:1], drafts])

        return propose


class DraftProposer(Proposer):
    """Greedy decode of the small draft model, ``L-1`` steps ahead of the
    committed stream.  The scan starts from the slot's persistent draft
    cache row and position but carries *copies* — the overshoot state a
    partially-rejected chain would leave behind is simply dropped, and the
    verify scan advances the persistent draft row by exactly the committed
    tokens instead."""

    name = "draft"

    @staticmethod
    def build(cfg, draft_cfg, ng_hash, push):
        assert draft_cfg is not None, \
            "the draft proposer needs draft_cfg/draft_params"

        def propose(dparams, draft, ng, ctx, pos, toks):
            L = toks.shape[0]

            def step(carry, _):
                caches, p, tok = carry
                logits, new = lm.decode_step(
                    dparams, {"caches": caches, "pos": p}, tok[None, None],
                    draft_cfg)
                nxt = jnp.argmax(logits[0], -1).astype(jnp.int32)
                return (new["caches"], new["pos"], nxt), nxt

            _, drafts = jax.lax.scan(step, (draft, pos, toks[0]), None,
                                     length=L - 1)
            return jnp.concatenate([toks[:1], drafts])

        return propose


PROPOSERS = {p.name: p for p in (NgramProposer, DraftProposer)}


@functools.lru_cache(maxsize=None)
def build_slot_tick(cfg: ArchConfig, spec_len: int = 0,
                    draft_cfg: Optional[ArchConfig] = None,
                    proposer: str = "ngram"):
    """Jitted tick: vmap of a per-slot chunk scan over ``lm.decode_step``.

    Per slot: a pool row (cache leaves ``[n, 1, S, ...]`` plus the n-gram
    suffix table ``ng [T]`` and its context window ``ctx [n_ctx]``), scalar
    pos, tokens ``[chunk]``, ``n_given`` (how many are prompt/pending tokens
    — the rest are sampled in-jit), active mask, PRNG key, temperature.
    Emits the ``[chunk]`` sampled tokens plus ``n_valid`` (committed count);
    position ``j``'s emission is the model's continuation after consuming
    token ``j``.  Inactive slots run (vmap is rectangular) but their state
    updates are masked out.

    Every tick — plain and speculative — *learns* in-jit: each fed token is
    written into the slot's suffix table under the hash of the ``n_ctx``
    tokens that preceded it, so the table is warm whichever arm the engine
    ran last (collisions only cost acceptance, never correctness).

    ``spec_len > 0`` builds the speculative variant (decode-only, all-greedy
    participants): the named ``proposer`` (:data:`PROPOSERS`) produces a
    ``spec_len``-token draft chain ahead of the scan; the scan verifies it
    with a carried ``valid`` mask that freezes non-positional caches, pos
    and table past the first mismatch, and ``n_valid`` reports the
    committed prefix (the accepted drafts plus the model's own correction
    token).  No sampling and no PRNG-key advance happen on this path — the
    keys pass through untouched.

    ``draft_cfg`` (not None) threads a draft-model parameter set through
    the tick as a second, non-donated argument: the signature grows to
    ``(params, dparams, pool, ...)`` and the pool carries per-slot draft
    cache rows under ``pool["draft"]`` which EVERY arm advances by each
    token it feeds the target (prefill chunks, plain decode, and the
    verify scan alike — under the same valid-mask/frozen-pos discipline),
    so whichever arm ran last, the draft state equals the committed stream.
    The draft shares the slot's position (it consumes exactly the target's
    tokens), and its rejected speculative writes die the same way the
    target's do: the frozen pos makes them land on one dead row.

    Memoized per (cfg, spec_len, draft_cfg, proposer): every ServeEngine
    over the same config shares one jit, so compiled tick specializations
    are reused across engine instances (the differential test harness
    builds hundreds).
    """
    table = cfg.serve.spec_table
    n_ctx = cfg.serve.spec_ctx
    assert table & (table - 1) == 0, "serve.spec_table must be a power of 2"
    assert 1 <= n_ctx <= len(_NG_MULTS), "serve.spec_ctx out of range"

    def ng_hash(ctx):
        h = jnp.uint32(0)
        for i in range(n_ctx):
            h = h ^ (ctx[i].astype(jnp.uint32) * jnp.uint32(_NG_MULTS[i]))
        return (h & jnp.uint32(table - 1)).astype(jnp.int32)

    def push(ctx, tok):
        if n_ctx == 1:
            return tok[None]
        return jnp.concatenate([ctx[1:], tok[None]])

    def feed_draft(dparams, draft, pos, tok, valid=None):
        """Advance the persistent per-slot draft row by one fed token at the
        shared (possibly frozen) ``pos``.  ``valid`` (verify scan only)
        applies the same positional/recurrent masking split the target's
        caches get: positional draft writes under a frozen pos land on one
        dead row the next accepted token overwrites, recurrent draft leaves
        must be frozen explicitly."""
        _, new = lm.decode_step(
            dparams, {"caches": draft, "pos": pos}, tok[None, None],
            draft_cfg)
        if valid is None:
            return new["caches"]
        return {
            t: (new["caches"][t] if t in _POSITIONAL_CACHE_TYPES
                else jax.tree.map(lambda o, n: jnp.where(valid, n, o),
                                  draft[t], new["caches"][t]))
            for t in draft}

    propose = PROPOSERS[proposer].build(cfg, draft_cfg, ng_hash, push) \
        if spec_len else None

    def one_slot(params, dparams, pool, pos, toks, n_given, active, reset,
                 key, temp):
        caches, ng, ctx = pool["caches"], pool["ng"], pool["ctx"]
        # a freshly joined slot starts from a zeroed cache row, an empty
        # suffix table, zeroed draft state and pos 0 — folded into the tick
        # so the join costs no eager scatter dispatches
        caches = jax.tree.map(
            lambda c: jnp.where(reset, jnp.zeros_like(c), c), caches)
        ng = jnp.where(reset, 0, ng)
        ctx = jnp.where(reset, 0, ctx)
        pos = jnp.where(reset, 0, pos)
        draft0 = None
        if draft_cfg is not None:
            draft0 = jax.tree.map(
                lambda c: jnp.where(reset, jnp.zeros_like(c), c),
                pool["draft"])
        L = toks.shape[0]

        if spec_len:
            # draft chain from the proposer arm this tick compiled for; the
            # propose scan carries throwaway state copies (rolling-window
            # draft caches wrap, so kept overshoot writes could alias valid
            # history — see DraftProposer)
            if L > 1:
                toks = propose(dparams, draft0, ng, ctx, pos, toks)

            def body(carry, j):
                caches, draft, pos, ng, win, valid = carry
                tok = toks[j]
                # learn the stream (valid steps only: rejected drafts are
                # not real stream tokens and would poison the table)
                hidx = ng_hash(win)
                ng = ng.at[hidx].set(jnp.where(valid, tok, ng[hidx]))
                win = jnp.where(valid, push(win, tok), win)
                logits, new = lm.decode_step(
                    params, {"caches": caches, "pos": pos}, tok[None, None],
                    cfg)
                nxt = jnp.argmax(logits[0], -1).astype(jnp.int32)
                # freeze only NON-positional state past the first mismatch:
                # KV rows a rejected step writes sit past the frozen pos —
                # dead until the next accepted token overwrites them — but
                # recurrent/rolling leaves cannot be position-rewound, so
                # their rejected writes must be masked out
                caches = {
                    t: (new["caches"][t] if t in _POSITIONAL_CACHE_TYPES
                        else jax.tree.map(
                            lambda o, n: jnp.where(valid, n, o),
                            caches[t], new["caches"][t]))
                    for t in caches}
                if draft_cfg is not None:
                    # the persistent draft row consumes the same committed
                    # tokens the target does, under the same freeze
                    draft = feed_draft(dparams, draft, pos, tok, valid)
                pos = jnp.where(valid, new["pos"], pos)
                nxt_ok = jnp.where(j + 1 < L,
                                   toks[jnp.minimum(j + 1, L - 1)] == nxt,
                                   False)
                return (caches, draft, pos, ng, win, valid & nxt_ok), \
                    (nxt, valid)

            (c2, d2, p2, ng2, ctx2, _), (emitted, valids) = jax.lax.scan(
                body, (caches, draft0, pos, ng, ctx, jnp.bool_(True)),
                jnp.arange(L))
            pool_f = {"caches": jax.tree.map(
                lambda o, n: jnp.where(active, n, o), caches, c2),
                "ng": jnp.where(active, ng2, ng),
                "ctx": jnp.where(active, ctx2, ctx)}
            if draft_cfg is not None:
                pool_f["draft"] = jax.tree.map(
                    lambda o, n: jnp.where(active, n, o), draft0, d2)
            n_valid = jnp.where(active, valids.sum(dtype=jnp.int32), 0)
            return (pool_f, jnp.where(active, p2, pos), key, emitted,
                    n_valid)

        def body(carry, j):
            caches, draft, pos, prev, key, ng, win = carry
            tok = jnp.where(j < n_given, toks[j], prev)
            hidx = ng_hash(win)
            ng = ng.at[hidx].set(tok)
            win = push(win, tok)
            logits, new = lm.decode_step(
                params, {"caches": caches, "pos": pos}, tok[None, None], cfg)
            if draft_cfg is not None:
                # the draft shadows every arm (prefill chunks and plain
                # decode too), so its state always equals the committed
                # stream whichever arm the engine picks next tick
                draft = feed_draft(dparams, draft, pos, tok)
            key, sub = jax.random.split(key)
            nxt = sample_traced(logits[0], sub, temp)
            return (new["caches"], draft, new["pos"], nxt, key, ng, win), nxt

        (c2, d2, p2, _, k2, ng2, ctx2), emitted = jax.lax.scan(
            body, (caches, draft0, pos, toks[0], key, ng, ctx),
            jnp.arange(L))
        pool_f = {"caches": jax.tree.map(
            lambda o, n: jnp.where(active, n, o), caches, c2),
            "ng": jnp.where(active, ng2, ng),
            "ctx": jnp.where(active, ctx2, ctx)}
        if draft_cfg is not None:
            pool_f["draft"] = jax.tree.map(
                lambda o, n: jnp.where(active, n, o), draft0, d2)
        return (pool_f, jnp.where(active, p2, pos),
                jnp.where(active, k2, key), emitted,
                jnp.where(active, jnp.int32(L), 0))

    vm = jax.vmap(one_slot, in_axes=(None, None, 0, 0, 0, 0, 0, 0, 0, 0))
    if draft_cfg is None:
        # draft-free ticks keep the historical 9-arg signature (dparams is
        # an empty pytree folded out of the jit)
        def tick(params, pool, pos, toks, n_given, active, reset, key,
                 temp):
            return vm(params, None, pool, pos, toks, n_given, active,
                      reset, key, temp)

        return jax.jit(tick, donate_argnums=(1,))
    return jax.jit(vm, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def build_row_snapshot(cfg: ArchConfig):
    """Jitted single-row gather: one slot's full pool row (every cache
    leaf, n-gram table, context window) as fresh buffers — the capture side
    of the prefix cache.  ``slot`` is traced, so one compile covers every
    slot; memoized per cfg like ``build_slot_tick``."""
    return jax.jit(lambda pool, slot: jax.tree.map(lambda p: p[slot], pool))


@functools.lru_cache(maxsize=None)
def build_seed_write(cfg: ArchConfig):
    """Jitted batched seed write: scatter ``k`` snapshot rows (and their
    frozen positions) into a donated slot pool in ONE dispatch — the join
    path's no-eager-scatter discipline applied to seeding.  Writing the
    whole row subsumes the reset-mask zeroing: a seeded slot starts from
    the snapshot state exactly as a reset slot starts from zeros, so no
    stale state can leak from the previous occupant."""
    def seed(pool, pos, idx, rows, new_pos):
        pool = jax.tree.map(lambda p, r: p.at[idx].set(r), pool, rows)
        return pool, pos.at[idx].set(new_pos)

    return jax.jit(seed, donate_argnums=(0, 1))


@functools.lru_cache(maxsize=None)
def build_pool_gather(cfg: ArchConfig):
    """Jitted batched row gather — the capture side of slot migration: ``k``
    slots' full pool rows (every cache leaf, n-gram table + context window,
    draft rows) plus their positions and PRNG keys as fresh buffers, ready
    to ``device_put`` at the destination placement.  Memoized per cfg; the
    jit re-specializes per source sharding, so one build covers every
    placed pool."""
    return jax.jit(lambda pool, pos, keys, idx: (
        jax.tree.map(lambda p: p[idx], pool), pos[idx], keys[idx]))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # [plen] int32
    max_new: int
    temperature: float = 0.0
    key: Any = None                      # private PRNG key (sampling)
    priority: str = "default"            # one of cfg.serve.classes
    pin_pool: Optional[int] = None       # admission restricted to this pool
    joined_version: int = 0              # params_version at admission: a
    #                                      request straddling a weight swap
    #                                      (joined old, finished new) is
    #                                      hybrid-state and must store
    #                                      neither results nor snapshots
    tokens: List[int] = dataclasses.field(default_factory=list)
    pool: int = -1                       # slot pool joined (-1: queued)
    slot: int = -1                       # slot within the pool
    prompt_off: int = 0
    pending_tok: int = -1                # emitted but not yet fed back
    seed_node: Any = None                # prefix-cache node this slot seeded
    #                                      from (ref held until eviction)
    # aging bookkeeping: scheduled ticks this prefill has sat out since it
    # last advanced; the peak is kept for the starvation regression tests
    deferred: int = 0
    max_deferred: int = 0
    # wall-clock marks for the latency benches (first-token / completion)
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    @property
    def prefilling(self) -> bool:
        return self.prompt_off < len(self.prompt)

    def output(self) -> np.ndarray:
        return np.asarray(self.tokens[:self.max_new], np.int32)


class SlotPool:
    """One donated slot pool: the per-pool device state the tick mutates.

    Every pool owns its cache rows, per-slot n-gram tables, positions, PRNG
    keys and reset mask; the compiled tick functions are NOT per-pool —
    ``build_slot_tick`` memoizes per (cfg, spec_len, draft_cfg, proposer),
    so pools of equal slot count share one jit.  ``pool_id`` is the
    engine-visible identity: tick jobs are recorded under
    ``jobs.pool_kind(kind, pool_id)`` (the per-pool cost EMAs the
    weighted-FRT arbitration scores) and acceptance under
    ``jobs.accept_kind(pool_id, arm)``.

    ``mesh`` (not None) *places* the pool: the donated state is committed
    to the mesh's devices under :func:`repro.runtime.sharding.pool_specs`
    (slot dim over ``data`` when divisible, trailing dims over ``model``
    at pool_tp > 1 — both reduction-free splits, so placement never
    touches bit-identicality), and the engine keeps a params copy on the
    same devices (``ServeEngine._params_for``).  ``lid`` is the pool's
    stable engine-local id: list position changes as pools drain away, the
    lid never does (requests address pools by it)."""

    def __init__(self, cfg: ArchConfig, pool_id: int, slots: int,
                 max_len: int, base_key,
                 draft_cfg: Optional[ArchConfig] = None,
                 mesh: Optional[Mesh] = None, lid: int = 0):
        self.pool_id = pool_id
        self.lid = lid
        self.mesh = mesh
        self.draining = False
        self.slots = slots
        one = lm.init_cache(cfg, 1, max_len)
        self.pool = {
            "caches": jax.tree.map(
                lambda x: jnp.zeros((slots,) + x.shape, x.dtype),
                one["caches"]),
            # per-slot n-gram suffix table + its context window: part of the
            # donated pool so draft proposal never leaves the device
            "ng": jnp.zeros((slots, cfg.serve.spec_table), jnp.int32),
            "ctx": jnp.zeros((slots, cfg.serve.spec_ctx), jnp.int32),
        }
        if draft_cfg is not None:
            # per-slot draft-model cache rows: same donated pool, so they
            # are reset-masked on join, snapshotted and seeded by the prefix
            # cache, and advanced in-jit with everything else
            done = lm.init_cache(draft_cfg, 1, max_len)
            self.pool["draft"] = jax.tree.map(
                lambda x: jnp.zeros((slots,) + x.shape, x.dtype),
                done["caches"])
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.pos_host = np.zeros((slots,), np.int64)   # device-sync-free view
        self.reset = np.zeros((slots,), bool)          # zero these rows in-jit
        self.keys = jax.random.split(base_key, slots)
        if mesh is not None:
            state = {"pool": self.pool, "pos": self.pos, "keys": self.keys}
            placed = jax.device_put(state,
                                    named(mesh, pool_specs(mesh, state)))
            self.pool, self.pos, self.keys = \
                placed["pool"], placed["pos"], placed["keys"]
        self.active: List[Optional[Request]] = [None] * slots

    def free_slots(self) -> int:
        return sum(r is None for r in self.active)

    def devices(self) -> tuple:
        """The device group this pool's state lives on (the default device
        for unplaced pools) — the disjointness key for parallel group ticks
        and the identity of the engine's placed-params cache."""
        if self.mesh is not None:
            return tuple(self.mesh.devices.flat)
        return (jax.devices()[0],)

    def put(self, x):
        """Commit a value (pytree ok) to this pool's placement, replicated.
        Host/uncommitted inputs and rows gathered on ANOTHER pool's mesh
        both land here as local buffers, so the following eager scatter or
        seed-write jit runs entirely on this pool's devices."""
        if self.mesh is not None:
            return jax.device_put(x, NamedSharding(self.mesh, P()))
        return jax.device_put(x, jax.devices()[0])


@dataclasses.dataclass
class _TickPlan:
    """One planned tick, built by ``ServeEngine._plan_tick`` and not yet
    run: the resolved arm/length/participants/layout plus an **async**
    dispatch thunk (launches the jit, does NOT block).  Splitting plan →
    dispatch → commit is what lets one scheduling round co-dispatch ticks
    for several device-placed pools and overlap them before blocking on
    any (the parallel group-tick path)."""
    sp: SlotPool
    mode: str
    spec: bool
    arm: str
    L: int
    part: List[Request]
    part_slots: List[int]
    n_given: np.ndarray
    idx: np.ndarray
    compact: bool
    compact_ok: bool
    job: Job
    extras: tuple
    dispatch: Any


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 128,
                 slots: int = 4, prefill_chunk: int = 16,
                 decode_chunk: int = 4, engine: Optional[Engine] = None,
                 seed: int = 0, compact_decode: Optional[bool] = None,
                 spec_decode: bool = False, pool_id: int = 0,
                 pools: int = 1,
                 class_pools: Optional[Dict[str, tuple]] = None,
                 prefix_cache: bool = False, params_version: int = 0,
                 draft: Optional[str] = None,
                 draft_cfg: Optional[ArchConfig] = None,
                 draft_params=None,
                 placements: Optional[Dict[int, Any]] = None,
                 autotune: Any = False):
        self.cfg = cfg
        self.params = params
        self.engine = engine or Engine()
        self.max_len = max_len
        self.slots = slots
        self.prefill_chunk = prefill_chunk
        self.decode_chunk = decode_chunk
        # lane-waste mitigation: when at least half the pool sits out a
        # decode tick (idle slots + prefill slots deferred by the min-FRT
        # rule), gather the participants into a compact batch before the
        # tick vmap so sat-out lanes stop burning decode FLOPs.  Costs one
        # gather + scatter-back of the participating cache rows per tick,
        # so it is gated on the pool being at least half idle — and within
        # that gate, layout is a MEASURED CostBook arm: compact_decode=None
        # (the default) lets ``Engine.choose_compact`` flip per tick from
        # per-pool compact-vs-full per-token EMAs; True/False pins it.
        self.compact_decode = compact_decode
        self.compact_ticks = 0
        # tunable knobs, seeded from config but hot-updatable (update()
        # handlers + the AutoTuner meta-controller): the live speculative
        # draft length, and the compaction-eligibility fraction — a decode
        # tick is compact-eligible when its participants fit in
        # ``int(slots * compact_frac)`` lanes.  0.5 reproduces the
        # historical ``slots // 2`` gate exactly.  Hot spec_len changes are
        # safe mid-stream: _tick_len caps L against every participant's
        # cache headroom and _plan_tick skips slots that would overrun.
        self.spec_len = int(cfg.serve.spec_len)
        self.compact_frac = 0.5
        # speculative in-tick decoding (see module docstring): offers the
        # engine extra tick arms — proposer draft + chunk-scan verify —
        # whose use is decided per tick from measured per-arm
        # acceptance/runtime EMAs.  ``pool_id`` offsets this engine's pool
        # ids (pools get pool_id..pool_id+pools-1) so acceptance and
        # runtime EMAs stay namespaced when several ServeEngines share one
        # Engine.
        self.spec_decode = spec_decode
        self.pool_id = pool_id
        self.spec_ticks = 0
        self.spec_proposed = 0      # draft tokens offered for verification
        self.spec_accepted = 0      # draft tokens committed
        # per-arm speculative counters ({"ngram": {...}, "draft": {...}})
        self.spec_arms: Dict[str, Dict[str, int]] = {}
        # draft-model proposer: draft="self" slices a truncated self-draft
        # out of the serve params (cfg.serve.draft_layers blocks + shared
        # head); an independent/distilled draft arrives as
        # draft_cfg+draft_params.  Either way the draft is acceptance-only:
        # it can never change outputs (engine.draft module docstring).
        from repro.engine.draft import slice_draft_params, truncated_draft_cfg
        self.draft_cfg: Optional[ArchConfig] = None
        self.draft_params = None
        # remembered so a hot params publish can re-slice the self-draft
        # (an independent draft is republished separately via draft_params)
        self._self_draft = draft == "self"
        if draft is not None:
            assert draft == "self", f"unknown draft mode {draft!r}"
            assert draft_cfg is None and draft_params is None, \
                "draft='self' derives the draft from the serve params"
            self.draft_cfg = truncated_draft_cfg(cfg)
            self.draft_params = slice_draft_params(params, cfg,
                                                   self.draft_cfg)
        elif draft_cfg is not None:
            assert draft_params is not None, \
                "an independent draft_cfg needs draft_params"
            self.draft_cfg = draft_cfg
            self.draft_params = draft_params
        # priority classes: name -> PriorityClass; the first table entry is
        # the default for requests submitted without a priority
        self.classes = {c.name: c for c in cfg.serve.classes}
        self._default_class = cfg.serve.classes[0].name
        # optional class -> admissible-pool routing (BatchedServer wires
        # this up); classes not listed may join any pool.  Validated here:
        # a typo'd class name or out-of-range pool id must fail at
        # construction, not mid-serve inside _admit
        self.class_pools = dict(class_pools or {})
        for cls, pids in self.class_pools.items():
            assert cls in self.classes, \
                f"class_pools names unknown class {cls!r}"
            assert pids and all(0 <= p < max(int(pools), 1) for p in pids), \
                f"class_pools[{cls!r}]={pids}: pool ids must be in " \
                f"[0, {max(int(pools), 1)})"
        self._base_key = jax.random.PRNGKey(seed)
        # device placement table: local pool id -> Mesh.  Values accepted
        # as a Mesh, a single jax.Device, or a device sequence (normalized
        # through runtime.sharding.pool_mesh at cfg.serve.pool_tp).  Pools
        # not listed stay on the default device — the legacy layout.
        self.placements: Dict[int, Mesh] = {}
        for i, plc in (placements or {}).items():
            assert 0 <= int(i) < max(int(pools), 1), \
                f"placements[{i}]: no such pool (pools={pools})"
            self.placements[int(i)] = self._as_mesh(plc)
        # per-device-group params copies for placed pools, built lazily on
        # first tick and invalidated by identity when params/draft_params
        # are hot-swapped (ServeEngine._params_for)
        self._pool_params: Dict[tuple, Dict[str, Any]] = {}
        # pool registry: each pool its own donated device state; pool 0
        # derives its slot keys straight from the engine seed (the exact
        # pre-multi-pool layout), later pools fold their index in.  List
        # position is transient (drained pools drop out); ``lid`` is the
        # stable identity requests/routing address pools by.
        self.pools: List[SlotPool] = [
            SlotPool(cfg, pool_id + i, slots, max_len,
                     self._base_key if i == 0
                     else jax.random.fold_in(self._base_key,
                                             0x7F000000 + i),
                     draft_cfg=self.draft_cfg,
                     mesh=self.placements.get(i), lid=i)
            for i in range(max(int(pools), 1))]
        self._next_local = max(int(pools), 1)
        self._last_mig_dst: Optional[int] = None
        self.migrated_slots = 0
        self.parallel_group_ticks = 0
        self._tick = build_slot_tick(cfg, 0, self.draft_cfg)
        self._compiled: set = set()    # (spec, tick_len, rows) already jitted
        # cross-request prefix cache + result cache (module docstring):
        # snapshots committed prompt prefixes at prefill tick boundaries and
        # seeds joining slots from the deepest match when the engine's
        # measured FRT comparison says the seed path answers first.
        # ``params_version`` keys the result cache: a hot weight swap bumps
        # it so stale answers cannot serve.
        sc = cfg.serve
        self.params_version = params_version
        self.prefix: Optional[PrefixCache] = PrefixCache(
            sc.prefix_cache_nodes, sc.prefix_min_len,
            sc.result_cache_entries) if prefix_cache else None
        self._analyzer = PrefixAnalyzer(sc.prefix_min_len,
                                        sc.prefix_pin_count,
                                        sc.prefix_history)
        self._n_submitted = 0
        self.queue: Deque[Request] = deque()
        self.tick_no = 0
        self.tokens_out = 0
        self._rid = itertools.count()
        self.hit_breakpoints: List[str] = []
        # closed-loop knob tuning (engine.autotune): the meta-controller
        # that makes the engine's OWN knobs (spec_len, compact_frac,
        # prefill_chunk, class weights) a result-aware Maestro decision.
        # ``autotune=True`` wires the default knob set; a dict passes
        # AutoTuner kwargs (knobs=, window=, ...); False leaves the knobs
        # config-pinned.  Built last: the tuner reads live engine state.
        self.autotuner = None
        if autotune:
            from repro.engine.autotune import AutoTuner
            kw = dict(autotune) if isinstance(autotune, dict) else {}
            self.autotuner = AutoTuner(self, **kw)

    # ------------------------------------------------ single-pool back-compat
    @property
    def active(self) -> List[Optional[Request]]:
        """Admitted requests across every pool (slot-ordered within pools).
        Read-only flattened view; per-pool state lives on ``self.pools``."""
        return [r for sp in self.pools for r in sp.active]

    @property
    def single_pool(self) -> bool:
        """True when scheduling can take the original single-pool path:
        one pool AND the default single-class table.  This path is kept
        decision-identical (not just output-identical) to the pre-priority
        engine — the differential harness pins it against the static
        oracle."""
        return len(self.pools) == 1 and len(self.classes) == 1

    # ------------------------------------------------------------- placement
    def _as_mesh(self, plc) -> Mesh:
        """Normalize a placement value (Mesh | Device | device sequence)
        to a pool mesh at the configured tensor-parallel degree."""
        if isinstance(plc, Mesh):
            return plc
        if isinstance(plc, (list, tuple)):
            return pool_mesh(plc, self.cfg.serve.pool_tp)
        return pool_mesh([plc], self.cfg.serve.pool_tp)

    def _pool(self, lid: int) -> Optional[SlotPool]:
        """Pool by stable local id (None once drained away)."""
        for sp in self.pools:
            if sp.lid == lid:
                return sp
        return None

    def _params_for(self, sp: SlotPool):
        """(target params, draft params) committed to the pool's placement.
        Unplaced pools share the engine's own references; placed pools get
        a per-device-group copy — replicated at pool_tp=1 (the
        bit-identicality default), tensor-parallel under the
        ``param_specs`` rules when the pool mesh carries a model axis.
        Cached by device group and invalidated by source identity, so a hot
        ``draft_params`` republish reaches placed pools on their next
        tick."""
        if sp.mesh is None:
            return self.params, self.draft_params
        ent = self._pool_params.setdefault(sp.devices(), {})
        if ent.get("src") is not self.params:
            if axis_size(sp.mesh, "model") > 1:
                sh = named(sp.mesh, param_specs(self.cfg, sp.mesh,
                                                fsdp=False))
            else:
                sh = NamedSharding(sp.mesh, P())
            ent["params"] = jax.device_put(self.params, sh)
            ent["src"] = self.params
        if self.draft_cfg is not None and \
                ent.get("dsrc") is not self.draft_params:
            ent["draft"] = jax.device_put(self.draft_params,
                                          NamedSharding(sp.mesh, P()))
            ent["dsrc"] = self.draft_params
        return ent["params"], ent.get("draft")

    def _group_busy(self, sp: SlotPool) -> float:
        """Occupancy fraction of the OTHER pools sharing any of this
        pool's devices — the contention term of placement-aware admission
        and of the arbitration's ``load`` input.  Zero when the pool's
        device group is exclusively its own."""
        devs = set(sp.devices())
        tot = occ = 0
        for o in self.pools:
            if o is sp or not devs & set(o.devices()):
                continue
            tot += o.slots
            occ += o.slots - o.free_slots()
        return occ / tot if tot else 0.0

    def add_pool(self, placement=None, slots: Optional[int] = None) -> int:
        """Elastic scale-out: append a new slot pool under load, optionally
        device-placed (``placement``: Mesh | Device | device sequence).
        Returns the pool's local id — immediately admissible, usable as
        ``submit(pool=...)``.  Slot PRNG keys derive from the engine seed
        and the local id exactly as construction-time pools do, so an
        engine built with N pools and one grown to N pools are
        key-identical."""
        lid = self._next_local
        self._next_local += 1
        mesh = None if placement is None else self._as_mesh(placement)
        sp = SlotPool(self.cfg, self.pool_id + lid,
                      slots or self.slots, self.max_len,
                      jax.random.fold_in(self._base_key, 0x7F000000 + lid),
                      draft_cfg=self.draft_cfg, mesh=mesh, lid=lid)
        self.pools.append(sp)
        if mesh is not None:
            self.placements[lid] = mesh
        return lid

    def drain_pool(self, lid: int) -> None:
        """Elastic scale-in, live: stop admitting to pool ``lid`` and
        migrate its in-flight slots out — up to ``cfg.serve.migrate_batch``
        per tick (bounding the per-tick stall), destination chosen by
        ``Engine.choose_migration_dst`` — then retire the empty pool.  The
        draining pool keeps offering candidate ticks until its last slot
        leaves, so nothing stops streaming; migrated continuations are
        greedy-bit-identical (``_migrate_slots``) and zero requests drop.
        Queued requests pinned to the pool fall back to open routing."""
        sp = self._pool(lid)
        assert sp is not None, f"no pool {lid}"
        assert any(o is not sp and not o.draining for o in self.pools), \
            "drain_pool would leave no admissible pool"
        sp.draining = True
        for req in self.queue:
            if req.pin_pool == lid:
                req.pin_pool = None

    def _drain_step(self) -> None:
        """One migration batch per draining pool per tick; pools empty of
        slots are removed.  A fully-saturated fleet simply defers the
        migration — the draining pool keeps serving its slots until
        capacity opens up."""
        for src in [sp for sp in self.pools if sp.draining]:
            occ = [(s, r) for s, r in enumerate(src.active)
                   if r is not None]
            if occ:
                opts = [{"pool": o.lid, "free": o.free_slots(),
                         "busy": self._group_busy(o),
                         "devices": len(o.devices())}
                        for o in self.pools
                        if o is not src and not o.draining
                        and o.free_slots() > 0]
                if not opts:
                    continue
                dst_lid = self.engine.choose_migration_dst(opts)
                dst = self._pool(dst_lid)
                self._last_mig_dst = dst_lid
                moves = occ[:min(self.cfg.serve.migrate_batch,
                                 dst.free_slots())]
                self._migrate_slots(src, dst, moves)
            if src.free_slots() == src.slots:
                self.pools.remove(src)
                self.placements.pop(src.lid, None)

    def _migrate_slots(self, src: SlotPool, dst: SlotPool,
                       moves: List[tuple]) -> None:
        """Move in-flight slots ``src -> dst``: one jitted batched gather
        of the full pool rows (every cache family, n-gram table + context,
        draft rows) plus positions and PRNG keys on the source placement,
        a ``device_put`` to the destination placement, and one jitted
        batched scatter — the seed-write jit, which writes whole rows and
        so subsumes reset-mask zeroing.  A slot's row + position + key
        fully determine its continuation (the tick consumes tokens one
        ``lm.decode_step`` at a time), so greedy outputs are bit-identical
        across any migration; a never-ticked join travels as its garbage
        row plus its still-pending reset flag, which the next tick zeroes
        in-jit as usual.  Measured as a ``serve_migrate`` job (per-token:
        the consumed positions moved), with the destination's pool-scoped
        EMA feeding ``choose_migration_dst`` and the arbitration's ``xfer``
        term."""
        k = len(moves)
        free = [s for s in range(dst.slots) if dst.active[s] is None]
        assert k and len(free) >= k
        dst_slots = free[:k]
        src_idx = jnp.asarray([s for s, _ in moves], jnp.int32)
        dst_idx = jnp.asarray(dst_slots, jnp.int32)
        gather = build_pool_gather(self.cfg)
        seed_fn = build_seed_write(self.cfg)
        ntok = max(int(sum(src.pos_host[s] for s, _ in moves)), 1)
        ck = ("migrate", src.devices(), dst.devices(), k)
        cold = ck not in self._compiled
        self._compiled.add(ck)
        job = Job("serve_migrate", tokens=ntok, meta={"cold": cold})
        extras = (Job(pool_kind("serve_migrate", dst.pool_id), tokens=ntok,
                      meta={"cold": cold}),)

        def thunk():
            rows, pos, keys = gather(src.pool, src.pos, src.keys, src_idx)
            rows, pos, keys = dst.put((rows, pos, keys))
            pool_n, pos_n = seed_fn(dst.pool, dst.pos, dst_idx, rows, pos)
            keys_n = dst.keys.at[dst_idx].set(keys)
            return jax.block_until_ready((pool_n, pos_n, keys_n))

        dst.pool, dst.pos, dst.keys = self.engine.run_job(
            job, thunk, extra=extras)
        for (s, r), d in zip(moves, dst_slots):
            dst.active[d] = r
            dst.pos_host[d] = src.pos_host[s]
            dst.reset[d] = bool(src.reset[s])
            r.pool, r.slot = dst.lid, d
            src.active[s] = None
            src.reset[s] = False
            src.pos_host[s] = 0
        self.migrated_slots += k

    # ------------------------------------------------------------- requests
    def submit(self, prompt, max_new: int = 16, temperature: float = 0.0,
               key=None, priority: Optional[str] = None,
               pool: Optional[int] = None) -> Request:
        """Queue a request.  ``key`` pins the request's private sampling
        stream (reproducibility); default derives one from the engine seed
        and the request id.  ``priority`` names a ``cfg.serve.classes``
        entry (default: the table's first class); ``pool`` pins admission
        to one slot pool (default: class routing, then least-loaded)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 1, "empty prompt"
        need = prompt.size + max_new + max(
            self.prefill_chunk, self.decode_chunk,
            self.spec_len if self.spec_decode else 0)
        assert need <= self.max_len, \
            f"prompt+max_new+chunk={need} exceeds max_len={self.max_len}"
        priority = priority or self._default_class
        assert priority in self.classes, \
            f"unknown priority {priority!r}; classes: {list(self.classes)}"
        assert pool is None or self._pool(pool) is not None, \
            f"no pool {pool}; live pools: {[sp.lid for sp in self.pools]}"
        rid = next(self._rid)
        if key is None:
            key = jax.random.fold_in(self._base_key, rid)
        req = Request(rid, prompt, max_new, temperature, key=key,
                      priority=priority, pin_pool=pool,
                      t_submit=time.perf_counter())
        if self.prefix is not None:
            # workload analyzer: count this prompt's grid prefixes and
            # periodically pin the hottest ones against LRU eviction
            self._analyzer.record(prompt)
            self._n_submitted += 1
            if self._n_submitted % 32 == 0:
                for p in self._analyzer.hot_prefixes()[:8]:
                    self.prefix.pin(p)
        self.queue.append(req)
        return req

    def _evict(self, req: Request) -> None:
        sp = self._pool(req.pool)
        # a request that straddled a weight swap (joined under an older
        # params_version) ran partly on old weights: its slot state and its
        # output are hybrid artifacts of neither version — store nothing
        fresh = req.joined_version == self.params_version
        if self.prefix is not None:
            if self.cfg.serve.snapshot_on_evict and fresh:
                # "commit extends the tree": snapshot the slot's full
                # committed path (prompt + generated) so an agent-loop
                # follow-up whose prompt extends this response seeds from
                # here.  Off by default — the per-evict row copy only pays
                # off on such workloads.
                path = np.concatenate(
                    [req.prompt, np.asarray(req.tokens, np.int32)]
                )[:int(sp.pos_host[req.slot])]
                if len(path) >= self.prefix.min_len and not (
                        (n := self.prefix.lookup(path)) is not None
                        and n.snapshot is not None
                        and n.version == self.params_version):
                    self._snapshot_slot(sp, req.slot, path)
            if req.seed_node is not None:
                self.prefix.release(req.seed_node)
                req.seed_node = None
            # finished greedy outputs become exact-hit answers for repeats
            # (version-gated: a hybrid-state output keyed under the current
            # version would serve an answer neither weight set produces)
            if fresh:
                self.prefix.result_store(req.prompt, req.max_new,
                                         req.temperature,
                                         self.params_version, req.output())
        sp.active[req.slot] = None
        req.pool = req.slot = -1
        req.t_done = time.perf_counter()
        req.done.set()

    def _finish_from_cache(self, req: Request, tokens: List[int]) -> None:
        """Answer a request straight from the result cache: no slot, no
        prefill, no decode — the first and last token land together."""
        req.tokens = list(tokens)
        now = time.perf_counter()
        req.t_first = req.t_first or now
        req.t_done = now
        self.tokens_out += len(req.tokens)
        req.done.set()

    def _snapshot_slot(self, sp: SlotPool, slot: int, path) -> None:
        """Capture one slot's pool row (jitted gather, measured as a
        ``serve_snapshot`` job) and commit it into the radix tree under
        ``path`` — the token prefix the slot has consumed so far.  The row
        is normalized to host numpy (``prefix_cache.to_host``) before it
        enters the tree: snapshots are placement-portable — captured on any
        pool's mesh, seeding any other pool (the seed-write jit re-commits
        host rows wherever the destination lives) — and hold no device
        buffers alive while cached."""
        cold = ("snapshot", sp.devices()) not in self._compiled
        self._compiled.add(("snapshot", sp.devices()))
        snap_fn = build_row_snapshot(self.cfg)
        job = Job("serve_snapshot", tokens=len(path), meta={"cold": cold})
        pjob = Job(pool_kind("serve_snapshot", sp.pool_id),
                   tokens=len(path), meta={"cold": cold})
        row = self.engine.run_job(
            job, lambda: jax.block_until_ready(snap_fn(sp.pool, slot)),
            extra=(pjob,))
        self.prefix.insert(path, snapshot=to_host(row),
                           version=self.params_version)

    def _allowed_pools(self, req: Request) -> List[int]:
        if req.pin_pool is not None:
            return [req.pin_pool]
        allowed = self.class_pools.get(req.priority)
        if allowed is not None:
            live = [p for p in allowed
                    if (sp := self._pool(p)) is not None
                    and not sp.draining]
            if live:
                return live
            # every routed pool drained away: fall back to open routing
            # rather than stranding the class
        return [sp.lid for sp in self.pools if not sp.draining]

    def _admit(self) -> None:
        """Join queued requests into free slots.  The cache-row zeroing and
        position reset are deferred into the next tick's jit (the ``reset``
        mask) — stale recurrent/rolling state must not leak between
        requests, but eager per-join scatters cost more than the tick's
        compute at smoke scale.  Only the tiny per-slot PRNG key is written
        eagerly (one batched scatter per pool for all its joiners).

        Routing: a pinned request only joins its pool; otherwise the
        class-routing table restricts the admissible pools, and among those
        the emptiest pool wins (ties: lowest pool id).  Requests whose
        admissible pools are all full stay queued — in order, without
        blocking later requests bound for a free pool — via one linear
        pass that rebuilds the queue.

        Prefix cache (when enabled): an exact result-cache hit answers the
        request here — it never takes a slot.  Otherwise a greedy request
        looks up its longest snapshotted prompt prefix, and if the engine's
        measured FRT comparison picks the seed path, the slot starts from
        the snapshot: ``prompt_off``/``pos`` begin at the cached depth and
        ``reset`` stays False (the seed write replaces the whole row, so no
        stale state survives).  Sampled requests never seed: the plain arm
        splits the slot's PRNG key once per scan step *including prefill
        steps*, so skipping prefill would shift a sampled request's key
        stream — greedy outputs ignore the key, which is exactly why the
        bit-identicality claim holds.  All seed rows land in ONE jitted
        batched write per pool (the join path's no-eager-scatter rule)."""
        joined: Dict[int, list] = {}
        seeds: Dict[int, list] = {}
        remaining: Deque[Request] = deque()
        for req in self.queue:
            if (self.prefix is not None and req.temperature <= 0
                    and (out := self.prefix.result_lookup(
                        req.prompt, req.max_new, req.temperature,
                        self.params_version)) is not None):
                self._finish_from_cache(req, out)
                continue
            cands = [p for p in self._allowed_pools(req)
                     if (c := self._pool(p)) is not None
                     and not c.draining and c.free_slots() > 0]
            if not cands:
                remaining.append(req)
                continue
            if self.placements and len(cands) > 1:
                # placement-aware admission: an engine decision over
                # occupancy-inflated per-pool per-token EMAs — a fast idle
                # device group beats a fast contended one
                pid = self.engine.choose_admission_pool(
                    [{"pool": p, "free": self._pool(p).free_slots(),
                      "busy": self._group_busy(self._pool(p)),
                      "devices": len(self._pool(p).devices())}
                     for p in cands])
            else:
                pid = max(cands,
                          key=lambda p: (self._pool(p).free_slots(), -p))
            sp = self._pool(pid)
            slot = next(s for s in range(sp.slots) if sp.active[s] is None)
            req.pool, req.slot = pid, slot
            req.joined_version = self.params_version
            sp.active[slot] = req
            node = None
            if self.prefix is not None and req.temperature <= 0:
                # >= 1 prompt token must remain to produce the first logits;
                # only snapshots captured under the CURRENT params version
                # may seed — old-version KV state under new weights would
                # replay stale state (the hot-swap staleness bug)
                node = self.prefix.longest_match(
                    req.prompt, limit=len(req.prompt) - 1,
                    version=self.params_version)
            if node is not None and self.engine.choose_prefix_admission(
                    node.depth, len(req.prompt) - node.depth,
                    pool_id=sp.pool_id) == "seed":
                self.prefix.acquire(node)
                req.seed_node = node
                req.prompt_off = node.depth
                sp.reset[slot] = False
                sp.pos_host[slot] = node.depth
                seeds.setdefault(pid, []).append((slot, node))
                self.prefix.seeded += 1
                self.prefix.tokens_avoided += node.depth
            else:
                if node is not None:
                    self.prefix.seed_declined += 1
                sp.reset[slot] = True
                sp.pos_host[slot] = 0
            joined.setdefault(pid, []).append((slot, req))
        self.queue = remaining
        for pid, js in joined.items():
            sp = self._pool(pid)
            idx = jnp.asarray([s for s, _ in js], jnp.int32)
            ks = jnp.stack([req.key for _, req in js])
            if sp.mesh is not None:
                ks = sp.put(ks)      # keep the scatter on the pool devices
            sp.keys = sp.keys.at[idx].set(ks)
        for pid, ss in seeds.items():
            sp = self._pool(pid)
            idx = jnp.asarray([s for s, _ in ss], jnp.int32)
            # snapshots are host numpy (placement-portable): stacked rows
            # arrive uncommitted, so the seed jit commits them wherever
            # this pool's donated state lives
            rows = jax.tree.map(lambda *rs: jnp.stack(rs),
                                *[n.snapshot for _, n in ss])
            new_pos = jnp.asarray([n.pos for _, n in ss], jnp.int32)
            cold = ("seed", sp.devices(), len(ss)) not in self._compiled
            self._compiled.add(("seed", sp.devices(), len(ss)))
            seed_fn = build_seed_write(self.cfg)
            depth = sum(n.depth for _, n in ss)
            job = Job("serve_seed", tokens=depth, meta={"cold": cold})
            pjob = Job(pool_kind("serve_seed", sp.pool_id), tokens=depth,
                       meta={"cold": cold})
            sp.pool, sp.pos = self.engine.run_job(
                job, lambda: jax.block_until_ready(seed_fn(
                    sp.pool, sp.pos, idx, rows, new_pos)),
                extra=(pjob,))

    # -------------------------------------------------------------- control
    def _inspect(self, what: str) -> Dict[str, Any]:
        info = {"tick": self.tick_no, "queue_depth": len(self.queue),
                "tokens_out": self.tokens_out,
                "paused": self.engine.controller.paused,
                "spec": {"enabled": self.spec_decode,
                         "ticks": self.spec_ticks,
                         "proposed": self.spec_proposed,
                         "accepted": self.spec_accepted,
                         "draft": None if self.draft_cfg is None
                         else self.draft_cfg.name,
                         "arms": {a: dict(c)
                                  for a, c in self.spec_arms.items()}},
                # decision telemetry ring buffer: every choose_* call the
                # engine made, with the per-arm scores and CostBook inputs
                # it saw — the explainability substrate (ROADMAP item 5)
                "decisions": list(self.engine.decisions),
                "prefix_cache": (self.prefix.stats()
                                 if self.prefix is not None
                                 else {"enabled": False}),
                "slots": [None if r is None else
                          {"rid": r.rid, "prompt_off": r.prompt_off,
                           "plen": len(r.prompt), "out": len(r.tokens),
                           "max_new": r.max_new, "priority": r.priority,
                           "deferred": r.deferred}
                          for r in self.active],
                "pools": [{"id": sp.pool_id, "lid": sp.lid,
                           "slots": sp.slots, "free": sp.free_slots(),
                           "draining": sp.draining,
                           "devices": ([str(d) for d in sp.devices()]
                                       if sp.mesh is not None else None)}
                          for sp in self.pools],
                "placement": {"placed_pools": len(self.placements),
                              "migrated_slots": self.migrated_slots,
                              "parallel_group_ticks":
                                  self.parallel_group_ticks},
                "classes": {n: {"weight": c.weight,
                                "max_defer": c.max_defer}
                            for n, c in self.classes.items()},
                # live tunable-knob values + the meta-controller's state:
                # the telemetry schema the gauntlet/autotune stack reads
                "knobs": {"spec_len": self.spec_len,
                          "compact_frac": self.compact_frac,
                          "prefill_chunk": self.prefill_chunk,
                          "decode_chunk": self.decode_chunk,
                          "class_weights": {n: c.weight
                                            for n, c in
                                            self.classes.items()}},
                "autotune": (self.autotuner.snapshot()
                             if self.autotuner is not None
                             else {"enabled": False}),
                "engine": self.engine.inspect()}
        return info

    def _apply_updates(self, updates: Dict[str, Any]) -> None:
        if "max_prefill_defer" in updates:
            self.engine.max_prefill_defer = int(updates["max_prefill_defer"])
        if "decode_chunk" in updates:
            self.decode_chunk = int(updates["decode_chunk"])
        if "prefill_chunk" in updates:
            self.prefill_chunk = int(updates["prefill_chunk"])
        if "spec_decode" in updates:
            self.spec_decode = bool(updates["spec_decode"])
        if "spec_len" in updates:
            # hot draft-length change: mid-stream safety comes from the
            # existing guards (_tick_len headroom cap, _plan_tick overrun
            # skip); a value the cache can't host simply shrinks the tick
            self.spec_len = max(int(updates["spec_len"]), 0)
        if "compact_frac" in updates:
            self.compact_frac = min(max(
                float(updates["compact_frac"]), 0.0), 1.0)
        if "class_weights" in updates:
            # per-class weight retune ({name: weight}): arbitration-only
            # state, so a frozen-dataclass replace at the tick boundary is
            # the whole swap — aging bounds (max_defer) are NOT tunable,
            # they are the starvation guarantee
            for name, w in dict(updates["class_weights"]).items():
                assert name in self.classes, \
                    f"class_weights names unknown class {name!r}"
                self.classes[name] = dataclasses.replace(
                    self.classes[name], weight=float(w))
        if "autotune" in updates:
            on = updates["autotune"]
            if on and self.autotuner is None:
                from repro.engine.autotune import AutoTuner
                kw = dict(on) if isinstance(on, dict) else {}
                self.autotuner = AutoTuner(self, **kw)
            elif not on:
                self.autotuner = None
        if "compact_decode" in updates:
            v = updates["compact_decode"]
            self.compact_decode = None if v is None else bool(v)
        if "draft_params" in updates:
            # hot draft republish: a draft is acceptance-only state, so the
            # swap needs no drain, no re-seed and no cache relayout — the
            # next draft-arm tick simply proposes from the new weights.
            # Ignored when no draft was configured at construction: hot
            # ENABLING a draft would need a pool relayout (draft rows).
            if self.draft_cfg is not None:
                self.draft_params = updates["draft_params"]
        if "prefix_cache" in updates:
            on = bool(updates["prefix_cache"])
            if on and self.prefix is None:
                sc = self.cfg.serve
                self.prefix = PrefixCache(sc.prefix_cache_nodes,
                                          sc.prefix_min_len,
                                          sc.result_cache_entries)
            elif not on and self.prefix is not None:
                # in-flight seeded requests keep their (host) refs on the
                # dropped tree; nothing reads it again, so just detach
                self.prefix = None
        if "params" in updates:
            # hot weight swap (the train->serve publish path): commit the
            # incoming host trees once and rebind — the fresh object
            # identity is what invalidates _params_for's per-device-group
            # cache, and any tick already planned this round closed over
            # the OLD reference at plan time, so it commits consistently
            # (its requests are version-gated out of storing results).
            self.params = jax.tree.map(jnp.asarray, updates["params"])
            if self._self_draft:
                from repro.engine.draft import slice_draft_params
                self.draft_params = slice_draft_params(
                    self.params, self.cfg, self.draft_cfg)
            # an explicit params_version in the same update wins; a bare
            # params swap auto-bumps so stale results can never serve
            self._bump_version(int(updates.get(
                "params_version", self.params_version + 1)))
        elif "params_version" in updates:
            # hot weight swap signaled out-of-band: new version keys the
            # result cache so stale answers cannot serve (old entries age
            # out of the LRU) and flushes stale prefix snapshots
            self._bump_version(int(updates["params_version"]))

    def _bump_version(self, version: int) -> None:
        """Move to a new params version: snapshots captured under any other
        version are flushed from the radix tree (they can never match again
        — ``longest_match`` filters by version — so keeping them is pure
        waste; ``serve.flush_prefix_on_publish=False`` keeps them for
        workloads that flip between versions).  The result cache needs no
        flush: its keys carry the version, old entries age out of the LRU."""
        if version == self.params_version:
            return
        self.params_version = int(version)
        if self.prefix is not None and self.cfg.serve.flush_prefix_on_publish:
            self.prefix.flush_versions(self.params_version)

    def update(self, **updates) -> None:
        """Queue a hot update through the controller mailbox — applied at
        the next tick boundary, like every control client's updates.
        ``update(params=..., params_version=...)`` is the weight-publish
        entry point (TrainLoop's ``publish_every`` hook calls it): in-flight
        planned ticks finish on the old reference, requests admitted after
        the boundary see the new weights, and zero requests drop."""
        self.engine.controller.send(M.update(**updates))

    def _poll(self) -> bool:
        r = self.engine.poll(self.tick_no, 0, self._inspect)
        self._apply_updates(r["updates"])
        return r["stopped"]

    def _check_breakpoints(self, emitted: int) -> None:
        m = {"emitted": float(emitted), "queue": float(len(self.queue)),
             "active": float(sum(r is not None for r in self.active)),
             "tokens_out": float(self.tokens_out)}
        for bp in self.engine.local_bps:
            if bp.check(m):
                self.hit_breakpoints.append(bp.name)
                self.engine.controller.paused = True
        for bp in list(self.engine.global_bps):
            if bp.update([emitted]):
                self.hit_breakpoints.append(bp.name)
                self.engine.controller.paused = True
                self.engine.global_bps.remove(bp)

    # ----------------------------------------------------------------- tick
    def _tick_len(self, sp: SlotPool, act: List[Request], mode: str,
                  chunk: int) -> int:
        """Adaptive tick length: no slot needs more than its remaining
        horizon, so trim the chunk to the longest one (rounded up to a
        power of two — the tick jit specializes on L, and an arbitrary L
        would compile once per distinct tail length).  ``cap`` keeps the
        tick inside the tightest participant's cache headroom: submit()
        reserves a chunk of slack, but a hot chunk-size update could
        otherwise leave a near-full slot unable to ever run again."""
        need, cap = 1, chunk
        for r in act:
            if mode != "prefill" and r.prefilling:
                continue
            h = (len(r.prompt) - r.prompt_off) if r.prefilling \
                else (r.max_new - len(r.tokens))
            need = max(need, min(h, chunk))
            cap = min(cap, self.max_len - int(sp.pos_host[r.slot]))
        L = 1
        while L < need:
            L *= 2
        L = min(L, chunk)
        while L > max(cap, 1):
            L //= 2
        return L

    def _pool_spec_ok(self, act: List[Request]) -> bool:
        """The speculative arms are only offered when every decode
        participant is greedy: verifying sampled continuations greedily
        would change their distribution (module docstring)."""
        dec = [r for r in act if not r.prefilling]
        return (self.spec_decode and self.spec_len > 1
                and bool(dec) and all(r.temperature <= 0 for r in dec))

    def _pool_spec_arms(self, act: List[Request]) -> tuple:
        """The proposer arms this pool's decode tick may run, by name.
        With a draft model loaded the engine arbitrates {plain, spec:ngram,
        spec:draft}; without, the historical {plain, spec:ngram} pair."""
        if not self._pool_spec_ok(act):
            return ()
        return ("ngram", "draft") if self.draft_cfg is not None \
            else ("ngram",)

    def _candidates(self) -> List[TickCandidate]:
        """One TickCandidate per (pool, composition) with work: the menu
        ``Engine.choose_serve_job`` arbitrates under weighted FRT.  A
        prefill candidate is ``aged`` as soon as any of its requests has
        sat out its class's ``max_defer`` scheduled ticks."""
        cands = []
        draining = any(sp.draining for sp in self.pools)
        for sp in self.pools:
            act = [r for r in sp.active if r is not None]
            if not act:
                continue
            pre = [r for r in act if r.prefilling]
            dec = [r for r in act if not r.prefilling]
            weight = lambda rs: sum(self.classes[r.priority].weight
                                    for r in rs)
            # placement terms (zero on the legacy unplaced layout, so the
            # arbitration scores reduce exactly to weighted FRT there):
            # ``load`` is the pool's device-group contention, ``xfer`` the
            # migration traffic about to land on it (pending draining
            # slots x the measured per-move cost, charged to the pool the
            # drain is currently routing into)
            load = self._group_busy(sp) if self.placements else 0.0
            xfer = 0.0
            if draining and self._last_mig_dst == sp.lid:
                pend = sum(o.slots - o.free_slots()
                           for o in self.pools if o.draining)
                t_mig = self.engine.costs.estimate_first(
                    [pool_kind("serve_migrate", sp.pool_id),
                     "serve_migrate"], COST_DEFAULTS["serve_migrate"])
                batches = -(-pend // max(self.cfg.serve.migrate_batch, 1))
                xfer = batches * t_mig
            if dec:
                arms = self._pool_spec_arms(act)
                cands.append(TickCandidate(
                    sp.pool_id, "decode", n_dec=len(dec), n_pre=len(pre),
                    chunk=self.decode_chunk, weight=weight(dec),
                    spec_len=self.spec_len if arms else 0,
                    arms=arms, load=load, xfer=xfer))
            if pre:
                overdue = max(r.deferred - self.classes[r.priority].max_defer
                              for r in pre)
                cands.append(TickCandidate(
                    sp.pool_id, "prefill", n_dec=len(dec), n_pre=len(pre),
                    pre_toks=sum(len(r.prompt) - r.prompt_off for r in pre),
                    chunk=self.prefill_chunk, weight=weight(pre),
                    aged=overdue >= 0, overdue=max(overdue, 0),
                    load=load, xfer=xfer))
        return cands

    def _age_prefills(self, part: List[Request]) -> None:
        """Post-tick aging bookkeeping: every ADMITTED prefill that did not
        advance this tick — sat out a decode tick on its own pool, or lives
        on a pool that lost the arbitration — ages one tick; participants
        reset.  The counters drive the per-class aging bound (weighted
        path) and the starvation regression tests."""
        ran = set(id(r) for r in part)
        for pool in self.pools:
            for r in pool.active:
                if r is None or not r.prefilling:
                    continue
                if id(r) in ran:
                    r.deferred = 0
                else:
                    r.deferred += 1
                    r.max_deferred = max(r.max_deferred, r.deferred)

    def _plan_tick(self, sp: SlotPool, act: List[Request],
                   mode: str) -> Optional[_TickPlan]:
        """Build one pool's tick without running it: resolve the
        speculative arm, tick length, participants, layout (compact vs
        full) and job records, and close over an **async** dispatch thunk
        — launching the jit without blocking, so a scheduling round can
        co-dispatch plans for several device-placed pools (the parallel
        group-tick path) before waiting on any of them."""
        spec_len = self.spec_len
        if mode == "spec":
            # bare-"spec" back-compat (old monkeypatched deciders): map to
            # the strongest proposer this engine carries
            mode = "spec:draft" if self.draft_cfg is not None \
                else "spec:ngram"
        spec = mode.startswith("spec:")
        arm = mode.split(":", 1)[1] if spec else ""
        if spec:
            L = self._tick_len(sp, act, mode, spec_len)
            if L < 2:
                mode, spec, arm = "decode", False, ""
                # a 1-token tick has nothing to draft
        if not spec:
            chunk = (self.prefill_chunk if mode == "prefill"
                     else self.decode_chunk)
            L = self._tick_len(sp, act, mode, chunk)
        toks = np.zeros((sp.slots, L), np.int32)
        n_given = np.ones((sp.slots,), np.int32)
        active = np.zeros((sp.slots,), bool)
        temps = np.zeros((sp.slots,), np.float32)
        part: List[Request] = []
        for r in act:
            if mode != "prefill" and r.prefilling:
                continue                      # prefill slots sit this one out
            if int(sp.pos_host[r.slot]) + L > self.max_len:
                continue                      # defensive: never overrun cache
            s = r.slot
            if r.prefilling:
                g = min(len(r.prompt) - r.prompt_off, L)
                toks[s, :g] = r.prompt[r.prompt_off:r.prompt_off + g]
                n_given[s] = g
            else:
                toks[s, 0] = r.pending_tok
            active[s] = True
            temps[s] = r.temperature
            part.append(r)
        if not part:
            return None
        # lane-waste mitigation: with >= half the pool sitting out this
        # decode tick, gather participants into a compact batch (padded to
        # a power of two with idle rows so the jit specializes on few batch
        # sizes).  Pad rows run inactive — their state round-trips
        # unchanged — and the scatter-back touches only gathered rows, so
        # sat-out slots keep their pending reset flags and cache state.
        part_slots = [r.slot for r in part]
        # layout arm: inside the half-idle eligibility gate, compact-vs-full
        # is either pinned by the config override or chosen per tick by the
        # engine from measured per-pool layout EMAs (Engine.choose_compact)
        compact_ok = mode != "prefill" \
            and len(part) <= int(sp.slots * self.compact_frac)
        compact = compact_ok and (
            self.compact_decode if self.compact_decode is not None
            else self.engine.choose_compact(sp.pool_id))
        if compact:
            nc = 1
            while nc < len(part):
                nc *= 2
            pads = [s for s in range(sp.slots) if s not in set(part_slots)]
            idx = np.asarray(part_slots + pads[:nc - len(part)], np.int32)
        else:
            idx = np.arange(sp.slots, dtype=np.int32)
        rows = len(idx)
        # fresh specialization tracking keeps compiles out of the EMAs; the
        # device group is part of the key because the shared jit
        # re-specializes (and re-compiles) per input sharding, so a placed
        # pool's first tick of a shape is compile-carrying even when an
        # unplaced pool already ran that shape
        ckey = (sp.devices(), arm if spec else False, L, rows)
        cold = ckey not in self._compiled
        self._compiled.add(ckey)
        kind = ("serve_prefill" if mode == "prefill"
                else spec_kind(arm) if spec else "serve_decode")
        ntok = L * len(part)
        job = Job(kind, tokens=ntok, meta={"cold": cold})
        # the same measurement lands under the pool-scoped kind too: the
        # per-pool EMA is the parallelism term of the multi-pool arbitration
        extras = [Job(pool_kind(kind, sp.pool_id), tokens=ntok,
                      meta={"cold": cold})]
        if spec:
            # arm-agnostic aggregate: the bootstrap fallback of the
            # per-pool t_tok chain (Engine._pool_t_tok)
            extras.append(Job("serve_spec_decode", tokens=ntok,
                              meta={"cold": cold}))
        if compact_ok:
            # layout EMAs only accumulate on layout-ELIGIBLE ticks, so the
            # compact-vs-full comparison is apples-to-apples (same
            # occupancy regime, not compact-halfidle vs full-busy)
            extras.append(Job(layout_kind(compact, sp.pool_id),
                              tokens=ntok, meta={"cold": cold}))
        # build_slot_tick memoizes per (cfg, spec_len, draft_cfg, proposer),
        # so this lookup is a cache hit after the first tick of each arm
        fn = build_slot_tick(self.cfg, spec_len, self.draft_cfg, arm) \
            if spec else self._tick
        params, dparams = self._params_for(sp)
        dargs = (dparams,) if self.draft_cfg is not None else ()
        if compact:
            jidx = jnp.asarray(idx)

            def dispatch():
                pool_c = jax.tree.map(lambda c: c[jidx], sp.pool)
                return fn(params, *dargs, pool_c, sp.pos[jidx],
                          jnp.asarray(toks[idx]), jnp.asarray(n_given[idx]),
                          jnp.asarray(active[idx]),
                          jnp.asarray(sp.reset[idx]), sp.keys[jidx],
                          jnp.asarray(temps[idx]))
        else:
            def dispatch():
                return fn(params, *dargs, sp.pool, sp.pos,
                          jnp.asarray(toks), jnp.asarray(n_given),
                          jnp.asarray(active), jnp.asarray(sp.reset),
                          sp.keys, jnp.asarray(temps))
        return _TickPlan(sp=sp, mode=mode, spec=spec, arm=arm, L=L,
                         part=part, part_slots=part_slots, n_given=n_given,
                         idx=idx, compact=compact, compact_ok=compact_ok,
                         job=job, extras=tuple(extras), dispatch=dispatch)

    def _commit_tick(self, plan: _TickPlan, outs) -> int:
        """Write one dispatched tick's results back: device state
        (pool/pos/keys), the host position view, token commits, evictions,
        prefill snapshots and speculative counters.  Returns the number of
        new tokens emitted; the caller aggregates aging, breakpoint and
        tick-count bookkeeping once per scheduling round."""
        sp, L, spec, part = plan.sp, plan.L, plan.spec, plan.part
        n_given, idx = plan.n_given, plan.idx
        if plan.compact:
            pool_n, pos_n, keys_n, emitted, nvalid = outs
            jidx = jnp.asarray(idx)
            sp.pool = jax.tree.map(lambda p, n: p.at[jidx].set(n),
                                   sp.pool, pool_n)
            sp.pos = sp.pos.at[jidx].set(pos_n)
            sp.keys = sp.keys.at[jidx].set(keys_n)
            sp.reset[idx] = False
            em_rows = np.asarray(emitted)
            em = np.zeros((sp.slots, L), em_rows.dtype)
            em[idx] = em_rows
            nv = np.zeros((sp.slots,), np.int64)
            nv[idx] = np.asarray(nvalid)
            self.compact_ticks += 1
        else:
            sp.pool, sp.pos, sp.keys, emitted, nvalid = outs
            sp.reset[:] = False           # zeroing landed inside the jit
            em = np.asarray(emitted)
            nv = np.asarray(nvalid).astype(np.int64)
        # the tick reports how far each slot really advanced: L for every
        # active slot on the plain arms, the committed prefix under spec
        sp.pos_host += nv
        n_new = 0
        now = time.perf_counter()
        for r in part:
            s, g = r.slot, int(n_given[r.slot])
            if r.prefilling:
                r.prompt_off += g
                if r.prefilling:
                    continue                  # prompt continues next tick
            need = r.max_new - len(r.tokens)
            last = int(nv[s]) if spec else L
            outs_r = em[s, g - 1:last][:need]
            if outs_r.size and r.t_first is None:
                r.t_first = now               # first-token latency mark
            r.tokens.extend(int(t) for t in outs_r)
            n_new += len(outs_r)
            if len(r.tokens) >= r.max_new:
                self._evict(r)
            else:
                r.pending_tok = int(em[s, last - 1])
        if self.prefix is not None and plan.mode == "prefill":
            # snapshot capture: a prefill tick boundary where the slot has
            # consumed exactly a prompt prefix (no decode output fed back
            # yet) is a reusable state — commit it into the radix tree
            # unless that path already owns a snapshot.  The guard on
            # pos_host == prompt_off excludes slots that transitioned to
            # decode mid-tick: their rows hold generated tokens too.
            for r in part:
                if (r.pool < 0 or r.prompt_off < self.prefix.min_len
                        or int(sp.pos_host[r.slot]) != r.prompt_off
                        or r.joined_version != self.params_version):
                    # the version gate: a slot that joined before a weight
                    # swap holds state computed under the OLD weights —
                    # capturing it under the current version would poison
                    # the tree for every later seed
                    continue
                path = r.prompt[:r.prompt_off]
                n = self.prefix.lookup(path)
                if n is not None and n.snapshot is not None \
                        and n.version == self.params_version:
                    continue          # stale-version snapshots re-capture
                self._snapshot_slot(sp, r.slot, path)
        if spec:
            proposed = (L - 1) * len(part)
            accepted = int(sum(int(nv[s]) - 1 for s in plan.part_slots))
            self.spec_ticks += 1
            self.spec_proposed += proposed
            self.spec_accepted += accepted
            st = self.spec_arms.setdefault(
                plan.arm, {"ticks": 0, "proposed": 0, "accepted": 0})
            st["ticks"] += 1
            st["proposed"] += proposed
            st["accepted"] += accepted
            if proposed:
                self.engine.observe_accept(sp.pool_id,
                                           accepted / proposed,
                                           arm=plan.arm)
        return n_new

    def _group_plans(self, winner: _TickPlan) -> List[_TickPlan]:
        """Opportunistic co-ticks for the parallel group-tick path: plain
        (non-speculative) plans for OTHER placed pools whose device groups
        are disjoint from the winner's (and each other's) — prefill when
        the pool still consumes prompt, decode otherwise (a prefill tick
        carries the pool's decoding slots along, so either way every slot
        with work advances).  The arbitration winner is unchanged —
        co-ticks only add work that would otherwise idle those devices;
        they run no speculative arm and record no extra decisions.  Empty
        without placements or when ``cfg.serve.parallel_ticks`` is off."""
        if not self.cfg.serve.parallel_ticks or winner.sp.mesh is None:
            return []
        used = set(winner.sp.devices())
        out = []
        for sp in self.pools:
            if sp is winner.sp or sp.mesh is None:
                continue
            devs = set(sp.devices())
            if devs & used:
                continue
            act = [r for r in sp.active if r is not None]
            if not act:
                continue
            mode = "prefill" if any(r.prefilling for r in act) else "decode"
            p = self._plan_tick(sp, act, mode)
            if p is None:
                continue
            used |= devs
            out.append(p)
        return out

    def tick(self) -> bool:
        """One engine iteration.  Returns False when stopped, True otherwise
        (including idle ticks).  Control messages land here — between ticks
        — and Inspect keeps answering while paused (the controller blocks
        inside poll until Resume).

        Scheduling: on the single-pool/single-class path the composition is
        the original ``Engine.choose_serve_tick`` min-FRT decision; with
        multiple pools or priority classes each pool's candidate ticks go
        through ``Engine.choose_serve_job`` (weighted FRT, placement-
        adjusted, + per-class aging bounds) and one pool wins the round —
        then, with device-placed pools, plain decode ticks for the other
        placed pools co-dispatch alongside the winner (``_group_plans``)
        so disjoint device groups decode concurrently."""
        if self._poll():
            return False
        self._drain_step()
        self._admit()
        spec_len = self.spec_len
        if self.single_pool:
            sp = self.pools[0]
            act = [r for r in sp.active if r is not None]
            if not act:
                return True
            n_pre = sum(r.prefilling for r in act)
            n_dec = len(act) - n_pre
            pre_toks = sum(len(r.prompt) - r.prompt_off
                           for r in act if r.prefilling)
            arms = self._pool_spec_arms(act)
            mode = self.engine.choose_serve_tick(
                n_dec, n_pre, pre_toks, self.decode_chunk,
                self.prefill_chunk,
                spec_len=spec_len if arms else 0,
                pool_id=sp.pool_id, arms=arms)
        else:
            cands = self._candidates()
            if not cands:
                return True
            gid, mode = self.engine.choose_serve_job(cands)
            sp = self._pool(gid - self.pool_id)
            act = [r for r in sp.active if r is not None]
        plan = self._plan_tick(sp, act, mode)
        if plan is None:
            return True
        group = self._group_plans(plan)
        if not group:
            outs = self.engine.run_job(
                plan.job, lambda: jax.block_until_ready(plan.dispatch()),
                extra=plan.extras)
            part = list(plan.part)
            n_new = self._commit_tick(plan, outs)
        else:
            # parallel group tick: launch every plan's jit before blocking
            # on any (async PJRT dispatch overlaps them on the disjoint
            # device groups), then block in dispatch order.  Each pool's
            # measured time is its elapsed-from-round-start — the
            # overlapped reality its EMAs should price — with cold flags
            # respected exactly as run_job would.
            plans = [plan] + group
            t0 = time.perf_counter()
            live = [(p, p.dispatch()) for p in plans]
            part, n_new = [], 0
            for p, outs in live:
                jax.block_until_ready(outs)
                dt = time.perf_counter() - t0
                self.engine.observe(p.job, dt)
                for j in p.extras:
                    self.engine.observe(j, dt)
                n_new += self._commit_tick(p, outs)
                part.extend(p.part)
            self.parallel_group_ticks += len(group)
        self._age_prefills(part)
        self.tokens_out += n_new
        self._check_breakpoints(n_new)
        self.tick_no += 1
        if self.autotuner is not None:
            # meta-control at the tick boundary, work ticks only: idle
            # ticks return above, so windows never accumulate empty time
            self.autotuner.on_tick()
        return True

    # ----------------------------------------------------------- convenience
    def run_until_done(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.tick():
                return
            if not self.queue and all(r is None for r in self.active):
                return
        raise RuntimeError("serve engine did not drain within max_ticks")

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 temperature: float = 0.0, seed=None,
                 priorities=None) -> np.ndarray:
        """Batch convenience with the old ``BatchedServer.generate``
        contract: rectangular prompts in, ``[B, max_new]`` tokens out.
        ``seed`` pins per-request sampling keys, so repeated calls with the
        same seed reproduce (per request, not per lockstep batch — the
        old static path shared one key across the batch).  ``priorities``
        optionally names a traffic class per prompt."""
        base = None if seed is None else jax.random.PRNGKey(seed)
        reqs = [self.submit(p, max_new, temperature,
                            key=None if base is None
                            else jax.random.fold_in(base, i),
                            priority=None if priorities is None
                            else priorities[i])
                for i, p in enumerate(prompts)]
        self.run_until_done()
        return np.stack([r.output() for r in reqs])
