"""The Engine: one Amber-style executor under training *and* serving.

The engine owns the control plane — the :class:`Controller` mailbox, the
durable control-replay log, and the registered breakpoints — and runs *jobs*
(train step, serve prefill, serve decode batch, checkpoint) expressed as
Maestro region workflows (``engine.jobs``).  Every job it runs is timed and
fed back into a :class:`CostBook`, so the scheduling decisions are made
against measured costs:

* ``choose_step_path`` — fused vs granulated training step.  When any
  interactivity is live (pending or replaying message, registered
  breakpoint, paused) the granulated path is *required* (messages must land
  at their per-microbatch points); otherwise the engine scores both job
  workflows under the ``completion`` objective and takes the cheaper one.
  This subsumes the PR-1 ``auto`` heuristic: the heuristic's answer falls
  out of the cost model instead of being hard-coded.
* ``choose_serve_tick`` — decode-only vs prefill tick composition for the
  serving engine: min first-response-time with an aging bound so prefills
  cannot starve (§4.5's min-FRT objective applied online).  When the
  serving engine offers the speculative arm, the decode choice further
  splits into plain vs speculative k-token ticks, decided from the pool's
  measured acceptance-rate EMA — acceptance is exactly the kind of
  measured, result-aware signal the CostBook exists for.
* ``choose_serve_job`` — the multi-pool generalization: N slot pools × K
  priority classes offer candidate ticks (``jobs.TickCandidate``) and the
  engine picks ONE (pool, composition) per round under weighted FRT —
  each candidate's ``serve_tick_workflow`` is costed with the pool's own
  measured per-token EMA (the parallelism term: a faster pool shows a
  lower measured time) and its FRT is divided by the summed
  priority-class weight of the requests it advances.  Per-class aging
  bounds hard-override the scores: a candidate carrying a request past
  its class's ``max_defer`` evicts every non-aged candidate from the
  round, so low-priority prefills cannot starve under a saturating
  high-priority stream.

Workers (``TrainLoop``, ``ServeEngine``) are engine *clients*: they hand the
engine their inspect callback and their job thunks and let it decide.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.core.breakpoints import GlobalCountBreakpoint, LocalBreakpoint
from repro.core.controller import Controller
from repro.core.estimator import CostBook
from repro.core.scheduler import (CostModel, compare_frt, completion_time,
                                  first_response_time,
                                  placement_adjusted_frt,
                                  weighted_first_response_time)
from repro.engine import jobs as J


class Engine:
    def __init__(self, controller: Optional[Controller] = None,
                 durable_log: Optional[str] = None,
                 max_prefill_defer: int = 4):
        self.controller = controller or Controller()
        if durable_log is not None and self.controller.durable_log_path is None:
            self.controller.attach_durable_log(durable_log)
        self.costs = CostBook()
        self.local_bps: List[Any] = []
        self.global_bps: List[Any] = []
        # decision telemetry ring buffer: every choose_* call appends
        # (decision kind, chosen arm, per-arm scores, and the CostBook
        # inputs the scores were computed from).  Bounded so a long-running
        # engine cannot grow without bound; surfaced through inspect() and
        # ServeEngine._inspect()["decisions"] — the explainability seed of
        # ROADMAP item 5.
        self.decisions: Deque[Dict[str, Any]] = deque(maxlen=512)
        self.jobs_run: Dict[str, int] = {}
        self.max_prefill_defer = max_prefill_defer
        self._prefill_defer = 0
        self._dispatch_rounds: Dict[int, int] = {}
        self._serve_rounds: Dict[int, int] = {}
        self._seed_rounds: Dict[int, int] = {}
        self._compact_rounds: Dict[int, int] = {}
        self._knob_rounds: Dict[str, int] = {}
        self._cm = CostModel(parallelism=1.0)

    # ---------------------------------------------------------- control plane
    def poll(self, step: int, microbatch: int,
             inspect_fn: Optional[Callable[[str], Any]] = None
             ) -> Dict[str, Any]:
        r = self.controller.poll(step, microbatch, inspect_fn)
        # breakpoint registrations live on the engine, not the worker
        for bp in self.controller.breakpoints:
            if isinstance(bp, GlobalCountBreakpoint):
                self.global_bps.append(bp)
            elif isinstance(bp, LocalBreakpoint):
                self.local_bps.append(bp)
        self.controller.breakpoints = []
        return r

    def interactive(self) -> bool:
        """Any live control demand that requires mid-step granularity."""
        c = self.controller
        return (c.paused or c.stopped or not c.mailbox.empty()
                or bool(self.local_bps) or bool(self.global_bps)
                or c.is_replaying())

    # ----------------------------------------------------------------- jobs
    def run_job(self, job: J.Job, fn: Callable[[], Any],
                extra: tuple = ()) -> Any:
        """Execute a job thunk, feed its measured runtime back into the cost
        book (per token when the job reports a token count, else per job).
        ``extra`` jobs record the same duration under additional kinds —
        e.g. a train step also measured as a dispatch-impl sample."""
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        self.observe(job, dt)
        for j in extra:
            self.observe(j, dt)
        return out

    def observe(self, job: J.Job, seconds: float) -> None:
        self.jobs_run[job.kind] = self.jobs_run.get(job.kind, 0) + 1
        if self.jobs_run[job.kind] == 1 or (job.meta or {}).get("cold"):
            return          # compile-carrying runs (first per kind, or a
            #                 shape the client knows is freshly specialized)
            #                 must not enter the EMA — a compile-inflated
            #                 cost would wedge the decisions
        self.costs.observe(job.kind, seconds)
        if job.tokens:
            self.costs.observe(job.kind + "_per_tok", seconds / job.tokens)

    def observe_accept(self, pool_id: int, frac: float,
                       arm: str = "ngram") -> None:
        """Feed one speculative tick's acceptance fraction (committed drafts
        / proposed drafts) into the pool's per-arm acceptance-rate EMA.
        Unlike job runtimes there is no compile-warm-up to skip — the first
        tick's acceptance is as real as the hundredth's — so this writes
        straight to the CostBook."""
        self.costs.observe_rate(J.accept_kind(pool_id, arm), frac)

    def _decide(self, kind: str, choice: str, **detail) -> str:
        # the deque's maxlen bounds the audit trail; every entry carries the
        # choice plus whatever scores/inputs the caller passed
        self.decisions.append({"decision": kind, "choice": choice, **detail})
        return choice

    def inspect(self) -> Dict[str, Any]:
        """Engine-level state for Inspect replies."""
        return {"costs": self.costs.snapshot(),
                "jobs_run": dict(self.jobs_run),
                "decisions_tail": list(self.decisions)[-5:],
                "breakpoints": len(self.local_bps) + len(self.global_bps)}

    # ------------------------------------------------------------- decisions
    def choose_step_path(self, forced: str = "auto", n_mb: int = 1) -> str:
        """Fused vs granulated training step (see module docstring)."""
        if forced in ("fused", "granulated"):
            return forced
        if self.interactive():
            # correctness, and also min-FRT: the control sink's first
            # response leaves after one microbatch on the granulated path
            return self._decide("step_path", "granulated",
                                why="interactive")
        t_f = self.costs.estimate("train_step_fused")
        if t_f is None:
            # explore before exploiting: granulated gets measured whenever
            # interactivity forces it, so an unmeasured fused path would
            # otherwise never get a second chance against a measured rival
            return self._decide("step_path", "fused", why="bootstrap")
        t_g = self.costs.estimate("train_step_granulated",
                                  J.COST_DEFAULTS["train_step_granulated"])
        scores = {}
        for path, t_step in (("fused", t_f), ("granulated", t_g)):
            wf = J.train_step_workflow(path, n_mb, t_step / max(n_mb, 1),
                                       t_apply=0.0)
            scores[path] = completion_time(wf, self._cm)
        best = min(scores, key=scores.get)
        return self._decide("step_path", best, scores=scores)

    def choose_dispatch_impl(self, tokens: int, forced: str = "auto") -> str:
        """Fused Pallas vs XLA MoE dispatch kernel, per shape (PR-2's
        adaptive path choice extended from loop granularity down to kernel
        choice).  Both impls run as alternative step workflows: the client
        tags each step it executes with a ``dispatch_kind`` job, so the
        CostBook accumulates a measured EMA per (impl, token-count) pair.
        Bootstrap explores fused first, then the XLA arm, then scores the
        two ``moe_dispatch_workflow`` candidates under ``completion_time``
        — the same objective the step-path decision uses.  (Each arm needs
        two runs before it is measured: the first carries the fresh jit
        specialization and is skipped by ``observe``.)"""
        if forced in ("fused", "xla"):
            return forced
        t_f = self.costs.estimate(J.dispatch_kind("fused", tokens))
        if t_f is None:
            return self._decide("dispatch_impl", "fused", why="bootstrap",
                                tokens=tokens)
        t_x = self.costs.estimate(J.dispatch_kind("xla", tokens))
        if t_x is None:
            return self._decide("dispatch_impl", "xla", why="explore",
                                tokens=tokens)
        scores = {}
        for impl, t_step in (("fused", t_f), ("xla", t_x)):
            wf = J.moe_dispatch_workflow(impl, tokens, t_step)
            scores[impl] = completion_time(wf, self._cm)
        best = min(scores, key=scores.get)
        # periodic re-explore: only the chosen impl runs (and refreshes its
        # EMA), so without this a stale or noise-poisoned measurement of
        # the loser would wedge the choice forever
        self._dispatch_rounds[tokens] = \
            self._dispatch_rounds.get(tokens, 0) + 1
        if self._dispatch_rounds[tokens] % 16 == 0:
            loser = "xla" if best == "fused" else "fused"
            return self._decide("dispatch_impl", loser, why="re-explore",
                                tokens=tokens, scores=scores)
        return self._decide("dispatch_impl", best, tokens=tokens,
                            scores=scores)

    def choose_serve_tick(self, decode_slots: int, prefill_slots: int,
                          prefill_tokens: int, decode_chunk: int,
                          prefill_chunk: int, spec_len: int = 0,
                          pool_id: int = 0,
                          arms: Tuple[str, ...] = ("ngram",)) -> str:
        """Tick composition: 'decode' (short, decode-state slots only),
        'prefill' (long, every active slot advances a prefill_chunk), or —
        when the serving engine offers it (``spec_len > 1``) — a speculative
        k-token decode arm ``spec:<proposer>`` from ``arms``.  The
        decode-vs-prefill choice is min-FRT with an aging bound; the
        plain-vs-spec-vs-spec split is a separate throughput decision over
        measured per-arm acceptance (``_choose_decode_arm``) taken only once
        a decode-composition tick has won."""
        if prefill_slots == 0:
            return self._choose_decode_arm(decode_slots, decode_chunk,
                                           spec_len, pool_id, arms)
        if decode_slots == 0:
            self._prefill_defer = 0
            return self._decide("serve_tick", "prefill", why="no_decoders")
        if self._prefill_defer >= self.max_prefill_defer:
            self._prefill_defer = 0
            return self._decide("serve_tick", "prefill", why="aging")
        t_tok = self.costs.estimate(
            "serve_decode_per_tok",
            self.costs.estimate(
                "serve_spec_decode_per_tok",
                self.costs.estimate("serve_prefill_per_tok", 1e-3)))
        chunk_now = min(prefill_tokens, prefill_chunk * prefill_slots)
        wf_d = J.serve_tick_workflow(decode_slots, decode_chunk, 0, t_tok)
        wf_p = J.serve_tick_workflow(decode_slots, prefill_chunk,
                                     chunk_now, t_tok)
        frt_d = first_response_time(wf_d, frozenset(), self._cm)
        frt_p = first_response_time(wf_p, frozenset(), self._cm)
        if frt_d <= frt_p:
            self._prefill_defer += 1
            self._decide("serve_tick", "decode",
                         frt={"decode": frt_d, "prefill": frt_p},
                         inputs={"t_tok": t_tok},
                         defer=self._prefill_defer)
            return self._choose_decode_arm(decode_slots, decode_chunk,
                                           spec_len, pool_id, arms)
        self._prefill_defer = 0
        return self._decide("serve_tick", "prefill",
                            frt={"decode": frt_d, "prefill": frt_p},
                            inputs={"t_tok": t_tok})

    def _pool_t_tok(self, pool_id: int) -> float:
        """Per-token tick cost for one pool: the pool's own measured EMAs
        first (``jobs.pool_kind`` — the weighted-FRT parallelism term), the
        fleet-wide EMAs as bootstrap for a pool that has not ticked yet,
        then the static prior."""
        tick_kinds = ("serve_decode", "serve_spec_decode:ngram",
                      "serve_spec_decode:draft", "serve_spec_decode",
                      "serve_prefill")
        chain = [J.pool_kind(k, pool_id) + "_per_tok" for k in tick_kinds]
        chain += [k + "_per_tok" for k in tick_kinds]
        return self.costs.estimate_first(chain, 1e-3)

    def choose_serve_job(self, cands: List[J.TickCandidate]
                         ) -> tuple[int, str]:
        """Pick the next tick across every slot pool: the Maestro decision
        over ``jobs.serve_tick_workflow`` candidates under weighted FRT.

        Each candidate is scored as the FRT of its tick workflow — costed
        with the candidate pool's measured per-token EMA — divided by its
        summed priority-class weight (``scheduler.weighted_first_response_time``),
        and the minimum wins.  Aged candidates (a participant past its
        class's ``max_defer``) pre-empt the scoring entirely: when any
        exist, only they are scored, so the aging bound is a hard
        guarantee, not a weight the arbitration could trade away.  A
        winning decode candidate that offers the speculative arm then runs
        the per-pool plain-vs-spec decision (``_choose_decode_arm``).

        Returns ``(pool_id, mode)`` with mode one of
        ``decode | prefill | spec``."""
        assert cands, "choose_serve_job needs at least one candidate"
        aged = [c for c in cands if c.aged]
        if aged:
            # several pools aged in the same round: the executor is serial,
            # so serve the most-overdue bound first (ties fall through to
            # the weighted scoring below)
            worst = max(c.overdue for c in aged)
            aged = [c for c in aged if c.overdue == worst]
        pool_scores: Dict[str, float] = {}
        best, best_score = None, float("inf")
        for c in (aged or cands):
            t_tok = self._pool_t_tok(c.pool_id)
            chunk_now = min(c.pre_toks, c.chunk * max(c.n_pre, 1)) \
                if c.mode == "prefill" else 0
            wf = J.serve_tick_workflow(c.n_dec, c.chunk, chunk_now, t_tok)
            frt = first_response_time(wf, frozenset(), self._cm)
            # placement terms: device-group contention inflates the FRT, a
            # pending migration headed at the pool adds the transfer the
            # tick must wait behind.  Both are zero for unplaced pools, so
            # this reduces exactly to weighted_first_response_time there.
            s = placement_adjusted_frt(frt, c.weight, c.load, c.xfer)
            pool_scores[f"{c.mode}@p{c.pool_id}"] = s
            if s < best_score:
                best, best_score = c, s
        self._decide("serve_job", f"{best.mode}@p{best.pool_id}",
                     scores=pool_scores, aged=bool(aged))
        if best.mode == "decode" and best.spec_len > 1:
            return best.pool_id, self._choose_decode_arm(
                best.n_dec, best.chunk, best.spec_len, best.pool_id,
                best.arms or ("ngram",))
        return best.pool_id, best.mode

    def choose_admission_pool(self, opts: List[dict]) -> int:
        """Placement-aware admission: pick which device-placed pool a newly
        admitted request's slot lives on.  Each option is
        ``{"pool": local_id, "free": int, "busy": float, "devices": int}``;
        the score is the pool's measured per-token EMA inflated by its
        device-group occupancy (``t_tok * (busy + 1)``) — the expected time
        the new slot waits per token on that hardware — so a fast idle pool
        beats a fast contended one, and a pool whose devices are shared
        beats nothing for free.  Ties break on free slots (desc) then pool
        id (asc), which reduces to the legacy most-free rule when no EMAs
        separate the pools yet."""
        assert opts, "choose_admission_pool needs at least one option"
        scores = {}
        best, best_key = None, None
        for o in opts:
            t_tok = self._pool_t_tok(o["pool"])
            s = t_tok * (max(o.get("busy", 0.0), 0.0) + 1.0)
            scores[f"p{o['pool']}"] = s
            key = (s, -o.get("free", 0), o["pool"])
            if best_key is None or key < best_key:
                best, best_key = o["pool"], key
        self._decide("admission_pool", f"p{best}", scores=scores)
        return best

    def choose_migration_dst(self, opts: List[dict]) -> int:
        """Where a draining pool's in-flight slots land: the same
        occupancy-inflated per-token score as admission, plus the measured
        per-row migration cost (``serve_migrate`` EMA) of moving INTO the
        candidate — a destination on the source's own devices copies for
        near-free, a cross-mesh one pays the transfer."""
        assert opts, "choose_migration_dst needs at least one option"
        scores = {}
        best, best_key = None, None
        for o in opts:
            t_tok = self._pool_t_tok(o["pool"])
            t_mig = self.costs.estimate_first(
                [J.pool_kind("serve_migrate", o["pool"]), "serve_migrate"],
                J.COST_DEFAULTS["serve_migrate"])
            s = t_tok * (max(o.get("busy", 0.0), 0.0) + 1.0) \
                + t_mig / max(o.get("free", 1), 1)
            scores[f"p{o['pool']}"] = s
            key = (s, -o.get("free", 0), o["pool"])
            if best_key is None or key < best_key:
                best, best_key = o["pool"], key
        self._decide("migration_dst", f"p{best}", scores=scores)
        return best

    def choose_prefix_admission(self, cached_tokens: int,
                                suffix_tokens: int,
                                pool_id: int = 0) -> str:
        """Reuse a cached prefix snapshot or recompute the prefill — the
        result-aware admission decision (returns ``"seed"`` or
        ``"prefill"``).

        Both alternatives are priced as region workflows under min-FRT
        (``scheduler.compare_frt``): ``jobs.prefix_seed_workflow`` pays one
        cache-row copy (the pool's measured ``serve_seed`` EMA — constant
        in the prefix length) plus the unshared suffix at the pool's
        per-token prefill EMA; ``jobs.prefill_workflow`` pays every prompt
        token.  "Copy what we already know" therefore wins exactly when the
        copy is cheaper than recomputing the cached tokens *on this pool's
        measured hardware*, not by assumption.  Bootstrap explores the seed
        arm (the only way its copy cost gets measured), and when prefill
        keeps winning the seed arm is re-explored every 16th decision so a
        stale or compile-poisoned copy EMA cannot wedge reuse off forever.
        """
        assert cached_tokens > 0 and suffix_tokens > 0
        t_seed = self.costs.estimate_first(
            [J.pool_kind("serve_seed", pool_id), "serve_seed"])
        if t_seed is None:
            return self._decide("prefix_admission", "seed", why="bootstrap",
                                pool=pool_id, cached=cached_tokens)
        t_tok = self.costs.estimate_first(
            [J.pool_kind("serve_prefill", pool_id) + "_per_tok",
             "serve_prefill_per_tok"], 1e-3)
        best, scores = compare_frt(
            {"seed": J.prefix_seed_workflow(cached_tokens, suffix_tokens,
                                            t_seed, t_tok),
             "prefill": J.prefill_workflow(cached_tokens + suffix_tokens,
                                           t_tok)}, self._cm)
        self._seed_rounds[pool_id] = self._seed_rounds.get(pool_id, 0) + 1
        if best == "prefill" and self._seed_rounds[pool_id] % 16 == 0:
            return self._decide("prefix_admission", "seed",
                                why="re-explore", pool=pool_id,
                                cached=cached_tokens, scores=scores)
        return self._decide("prefix_admission", best, pool=pool_id,
                            cached=cached_tokens, suffix=suffix_tokens,
                            scores=scores)

    def _choose_decode_arm(self, decode_slots: int, decode_chunk: int,
                           spec_len: int, pool_id: int,
                           arms: Tuple[str, ...] = ("ngram",)) -> str:
        """The decode arm family, per slot pool: plain multi-token decode vs
        one speculative arm per offered proposer (``spec:ngram``,
        ``spec:draft``, ...).

        Every arm is scored as a ``jobs.serve_decode_workflow`` region
        workflow under ``completion_time``, normalized by the tokens a tick
        is *expected to commit*: ``decode_chunk`` for the plain arm (every
        scan step commits a token), ``1 + a·(spec_len-1)`` for a speculative
        arm, with ``a`` that arm's measured per-pool acceptance-rate EMA
        (``jobs.accept_kind(pool_id, arm)``) and its verify-tick cost that
        arm's own runtime EMA (``jobs.spec_kind(arm)``) — the draft arm pays
        the draft model's propose scan inside the dispatch, so its per-step
        cost is measured higher and only its higher acceptance can win the
        score back.  Each speculative arm is bootstrap-explored until both
        its EMAs exist (acceptance can only be measured by running the arm);
        afterwards the losing arms rotate through a re-explore slot every
        16th round so a stale acceptance or runtime EMA cannot wedge the
        choice — workloads drift between repetitive and incompressible
        text, and a draft republish changes acceptance mid-stream."""
        if spec_len <= 1 or not arms:
            return "decode"
        per: Dict[str, tuple] = {}
        for arm in arms:
            a = self.costs.estimate(J.accept_kind(pool_id, arm))
            t_s = self.costs.estimate(J.spec_kind(arm) + "_per_tok")
            if a is None or t_s is None:
                return self._decide("serve_decode_arm", f"spec:{arm}",
                                    why="bootstrap", pool=pool_id)
            per[arm] = (a, t_s)
        t_p = self.costs.estimate("serve_decode_per_tok")
        if t_p is None:
            return self._decide("serve_decode_arm", "decode", why="explore",
                                pool=pool_id)
        inputs: Dict[str, float] = {"t_plain": t_p}
        scores = {"decode": completion_time(
            J.serve_decode_workflow("plain", decode_slots, decode_chunk,
                                    t_p), self._cm) / max(decode_chunk, 1)}
        for arm, (a, t_s) in per.items():
            wf = J.serve_decode_workflow("spec", decode_slots, spec_len,
                                         t_s, accept=a)
            scores[f"spec:{arm}"] = completion_time(wf, self._cm) / max(
                1.0 + a * (spec_len - 1), 1e-9)
            inputs[f"accept:{arm}"] = a
            inputs[f"t_spec:{arm}"] = t_s
        best = min(scores, key=scores.get)
        self._serve_rounds[pool_id] = self._serve_rounds.get(pool_id, 0) + 1
        r = self._serve_rounds[pool_id]
        if r % 16 == 0:
            # rotate through the losers so every arm's EMAs stay fresh even
            # with 3+ arms in the family
            losers = sorted(k for k in scores if k != best)
            loser = losers[(r // 16 - 1) % len(losers)]
            return self._decide("serve_decode_arm", loser, why="re-explore",
                                pool=pool_id, scores=scores, inputs=inputs)
        return self._decide("serve_decode_arm", best, pool=pool_id,
                            scores=scores, inputs=inputs)

    def choose_knob(self, name: str, values: Tuple[Any, ...]) -> Any:
        """Pick the next arm for one tuned engine knob (autotune's
        meta-decision): the same bootstrap → exploit → re-explore
        discipline every other choice here follows, over the windowed
        cost-per-token EMAs the AutoTuner records under
        ``jobs.knob_kind(name, value)``.

        Bootstrap visits every unmeasured arm in listed order (a knob
        value's cost can only be learned by living under it for a
        window); once all arms carry an EMA the cheapest wins; and every
        16th round the losers rotate through a re-explore slot — knob
        costs are workload-dependent, so a value that lost under
        yesterday's traffic must keep getting re-measured under today's.
        The chosen arm lands in the decision deque like every ``choose_*``
        call, so ``dump_decisions`` explains knob moves with the same
        scores/inputs schema."""
        assert values, f"knob {name} offers no values"
        scores: Dict[str, float] = {}
        for v in values:
            t = self.costs.estimate(J.knob_kind(name, v))
            if t is None:
                self._decide("autotune_knob", str(v), knob=name,
                             why="bootstrap")
                return v
            scores[str(v)] = t
        best = min(scores, key=scores.get)
        self._knob_rounds[name] = self._knob_rounds.get(name, 0) + 1
        r = self._knob_rounds[name]
        if r % 16 == 0 and len(values) > 1:
            losers = sorted(k for k in scores if k != best)
            loser = losers[(r // 16 - 1) % len(losers)]
            self._decide("autotune_knob", loser, knob=name,
                         why="re-explore", scores=scores, inputs=scores)
            return next(v for v in values if str(v) == loser)
        self._decide("autotune_knob", best, knob=name, scores=scores,
                     inputs=scores)
        return next(v for v in values if str(v) == best)

    def choose_compact(self, pool_id: int) -> bool:
        """Compact vs full batch layout for an eligible decode tick (at
        least half the pool sitting out), per slot pool — the promotion of
        the old default-off ``compact_decode`` flag to a measured CostBook
        arm.

        Both layouts advance the same participants by the same chunk, so
        the cheaper *measured per-token tick time* (``jobs.layout_kind``,
        recorded only on eligible ticks so both EMAs cover the same
        occupancy regime) wins directly — no workflow shape differs between
        them.  Bootstrap explores compact first (the gather/scatter cost
        can only be measured by running it), then full, and the losing
        layout is re-explored every 16th eligible tick so a drifting
        machine or pool shape cannot wedge the choice.  The config override
        (``ServeEngine(compact_decode=True/False)``) bypasses this decision
        entirely."""
        t_c = self.costs.estimate(J.layout_kind(True, pool_id) + "_per_tok")
        if t_c is None:
            return self._decide("serve_compact", "compact", why="bootstrap",
                                pool=pool_id) == "compact"
        t_f = self.costs.estimate(J.layout_kind(False, pool_id) + "_per_tok")
        if t_f is None:
            return self._decide("serve_compact", "full", why="explore",
                                pool=pool_id) == "compact"
        scores = {"compact": t_c, "full": t_f}
        best = min(scores, key=scores.get)
        self._compact_rounds[pool_id] = \
            self._compact_rounds.get(pool_id, 0) + 1
        if self._compact_rounds[pool_id] % 16 == 0:
            loser = "full" if best == "compact" else "compact"
            return self._decide("serve_compact", loser, why="re-explore",
                                pool=pool_id, scores=scores,
                                inputs=scores) == "compact"
        return self._decide("serve_compact", best, pool=pool_id,
                            scores=scores, inputs=scores) == "compact"
