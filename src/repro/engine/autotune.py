"""Closed-loop knob autotuning: the engine's own knobs as a Maestro decision.

Every decision surface the engine carries — tick composition, spec arm,
layout, admission, placement — is result-aware: arms are measured, the
CostBook scores them, bootstrap/re-explore keeps the EMAs honest.  But the
*knobs* those decisions run under (``spec_len``, the compaction threshold,
``prefill_chunk``, priority-class weights) stayed config-pinned constants.
This module closes the loop: an :class:`AutoTuner` attached to a
:class:`~repro.engine.serve.ServeEngine` treats each knob as one more
decision family.

Mechanics — deliberately the same discipline as every ``Engine.choose_*``:

* Time is split into fixed **windows** of work ticks.  Each window runs
  entirely under one (knob, value) arm; at the window boundary the tuner
  records the window's measured cost — wall seconds per committed token by
  default — under ``jobs.knob_kind(name, value)`` in the shared CostBook.
* The first window after an arm switch is a **warm-up**: a changed
  ``spec_len`` or chunk compiles fresh tick jits, and a compile-carrying
  window entering the EMA would wedge the choice exactly the way
  ``Engine.observe`` guards against for jobs.  Warm-up windows are
  counted but not recorded.
* Knobs are tuned **round-robin** (coordinate descent): one knob owns the
  measurement at a time, so a window's cost is attributable to the arm
  that ran it.  Arm selection is :meth:`Engine.choose_knob` — bootstrap
  every unmeasured value, exploit the cheapest, re-explore a rotating
  loser every 16th round — so every knob move lands in the decision
  telemetry deque with its scores, like any other engine choice.
* Application goes through the same handlers ``update()`` uses
  (``ServeEngine._apply_updates``), called directly at the tick boundary
  the tuner runs on — the tuner IS a control client, just an in-process
  one, so it can never apply a knob mid-tick.

Greedy bit-identicality is preserved by construction: every tuned knob is
one the engine already accepts as a hot update, and the differential
harness sweeps exactly those updates (chunk flips, spec toggles) against
the static oracle.  ``tests/test_autotune.py`` pins it anyway.

Measurement is injectable (``measure=``): unit tests hand the tuner a
synthetic cost function and prove convergence deterministically; the real
default reads the engine's wall clock and token counter.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.engine import jobs as J

__all__ = ["Knob", "AutoTuner", "default_knobs"]


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable engine knob: a name, the discrete arm values the tuner
    may pick from, and how a value becomes an ``_apply_updates`` dict —
    ``key`` for plain ``{key: value}`` knobs, ``wrap`` for structured ones
    (class weights).  ``read`` recovers the engine's current value so the
    tuner starts from — and accounts the first window to — whatever the
    config pinned."""
    name: str
    values: Tuple[Any, ...]
    key: str = ""
    wrap: Optional[Callable[[Any], Dict[str, Any]]] = None
    read: Optional[Callable[[Any], Any]] = None

    def updates(self, value) -> Dict[str, Any]:
        if self.wrap is not None:
            return self.wrap(value)
        assert self.key, f"knob {self.name}: no key and no wrap"
        return {self.key: value}

    def current(self, eng) -> Any:
        if self.read is not None:
            return self.read(eng)
        return getattr(eng, self.key)


def default_knobs(eng) -> List[Knob]:
    """The stock knob set for one engine, filtered to what the engine can
    actually honor: spec_len arms only when speculative decoding is live
    (and capped so prompt+max_new+spec_len stays inside max_len for
    typical traffic), chunk arms capped at the configured chunk (larger
    values would change submit()'s admission contract mid-flight), class
    weights only when there are classes to trade off."""
    knobs: List[Knob] = []
    pc = int(eng.prefill_chunk)
    arms = tuple(c for c in (1, 2, 4, 8, 16, 32) if c <= pc)
    if len(arms) > 1:
        knobs.append(Knob("prefill_chunk", arms, key="prefill_chunk"))
    if eng.spec_decode:
        cap = max(eng.max_len // 8, 2)
        sarms = tuple(s for s in (2, 4, 8) if s <= cap)
        if len(sarms) > 1:
            knobs.append(Knob("spec_len", sarms, key="spec_len"))
    knobs.append(Knob("compact_frac", (0.25, 0.5, 0.75),
                      key="compact_frac"))
    for name, c in eng.classes.items():
        if len(eng.classes) < 2:
            break
        base = float(c.weight)
        knobs.append(Knob(
            f"weight:{name}",
            tuple(round(base * m, 4) for m in (0.5, 1.0, 2.0)),
            wrap=lambda v, _n=name: {"class_weights": {_n: v}},
            read=lambda e, _n=name: float(e.classes[_n].weight)))
    return knobs


class AutoTuner:
    """The meta-controller: windowed measurement + round-robin knob moves.

    ``window`` is in WORK ticks (the engine only calls :meth:`on_tick`
    on ticks that dispatched something).  ``measure`` overrides the cost
    sample for a closing window: a callable of the stats dict
    ``{"wall_s", "tokens", "ticks"}`` returning seconds-per-token-like
    cost, or ``None`` to drop the window.  ``warmup`` is the number of
    post-switch windows discarded before measurement (default 1: the
    compile window)."""

    def __init__(self, eng, knobs: Optional[List[Knob]] = None,
                 window: int = 32, warmup: int = 1,
                 measure: Optional[Callable[[Dict[str, float]],
                                            Optional[float]]] = None):
        assert window >= 1
        self.eng = eng
        self.knobs = list(knobs) if knobs is not None else default_knobs(eng)
        assert self.knobs, "AutoTuner needs at least one knob"
        names = [k.name for k in self.knobs]
        assert len(set(names)) == len(names), f"duplicate knobs: {names}"
        self.window = int(window)
        self.warmup = int(warmup)
        self.measure = measure or self._measure_wall
        self.windows = 0              # windows closed (incl. warm-ups)
        self.moves = 0                # arm applications that changed value
        self._ki = 0                  # knob being measured (round-robin)
        self._warm = 0                # warm-up windows left to discard
        self._ticks = 0
        self._t0 = time.perf_counter()
        self._tok0 = int(eng.tokens_out)
        # current value per knob, read off the live engine so the first
        # window is accounted to the config-pinned arm (which may not be
        # in ``values`` — that's fine, it just never gets re-chosen)
        self.current: Dict[str, Any] = {k.name: k.current(eng)
                                        for k in self.knobs}

    # ------------------------------------------------------------ measurement
    @staticmethod
    def _measure_wall(stats: Dict[str, float]) -> Optional[float]:
        """Default window cost: wall seconds per committed token.  A
        window that committed nothing has no signal — dropped rather than
        scored, so a starved window can't poison an arm's EMA with a
        divide-by-almost-zero artifact."""
        if stats["tokens"] <= 0:
            return None
        return stats["wall_s"] / stats["tokens"]

    def _window_stats(self) -> Dict[str, float]:
        return {"wall_s": time.perf_counter() - self._t0,
                "tokens": float(int(self.eng.tokens_out) - self._tok0),
                "ticks": float(self._ticks)}

    # ------------------------------------------------------------------ loop
    def on_tick(self) -> None:
        """Called by the engine at the end of every WORK tick.  Closes the
        window when due, records the measurement, rotates to the next
        knob, asks ``Engine.choose_knob`` for its next arm, applies it."""
        self._ticks += 1
        if self._ticks < self.window:
            return
        stats = self._window_stats()
        self.windows += 1
        if self._warm > 0:
            # post-switch warm-up window (compile-carrying): discard, and
            # only start measuring once the warm-ups have elapsed
            self._warm -= 1
        else:
            knob = self.knobs[self._ki % len(self.knobs)]
            cost = self.measure(stats)
            if cost is not None:
                self.eng.engine.costs.observe(
                    J.knob_kind(knob.name, self.current[knob.name]),
                    float(cost))
            # measured (or dropped) a settled window: move on — next knob
            # in the rotation picks its next arm
            self._ki += 1
            nxt = self.knobs[self._ki % len(self.knobs)]
            value = self.eng.engine.choose_knob(nxt.name, nxt.values)
            if value != self.current[nxt.name]:
                self.eng._apply_updates(nxt.updates(value))
                self.current[nxt.name] = value
                self.moves += 1
                self._warm = self.warmup
        self._ticks = 0
        self._t0 = time.perf_counter()
        self._tok0 = int(self.eng.tokens_out)

    # ------------------------------------------------------------- telemetry
    def snapshot(self) -> Dict[str, Any]:
        """The ``_inspect()["autotune"]`` payload: live arm per knob, the
        knob currently owning the measurement window, and each arm's
        CostBook EMA — enough to explain every move without replaying the
        decision deque."""
        book = self.eng.engine.costs
        return {
            "enabled": True,
            "window": self.window,
            "windows": self.windows,
            "moves": self.moves,
            "measuring": self.knobs[self._ki % len(self.knobs)].name,
            "current": dict(self.current),
            "arms": {k.name: {str(v): book.estimate(J.knob_kind(k.name, v))
                              for v in k.values}
                     for k in self.knobs},
        }
