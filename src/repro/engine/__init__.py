"""Online Maestro: the unified engine layer.

Training and serving run as region-structured, result-aware *jobs* under one
Amber-style executor — see ``engine.engine.Engine`` (control plane + cost
book + decisions), ``engine.jobs`` (the job -> region-workflow mapping), and
``engine.serve.ServeEngine`` (continuous batching).  ``engine.prefix_cache``
makes prefilled state a reusable artifact: a radix tree of slot-row
snapshots plus an exact-hit result cache, consulted at admission through a
measured FRT decision.  ``runtime.loop`` and ``runtime.serve`` are clients
of this layer.  ``engine.loadgen`` generates the scenario-diverse
workloads (and the virtual-time drive harness) the gauntlet grades;
``engine.autotune`` closes the loop, tuning the engine's own knobs from
windowed measurement under the same CostBook discipline.
"""
from repro.engine.autotune import AutoTuner, Knob
from repro.engine.draft import (distill_draft, slice_draft_params,
                                small_draft_cfg, truncated_draft_cfg)
from repro.engine.engine import Engine
from repro.engine.jobs import (Job, TickCandidate, accept_kind,
                               checkpoint_workflow, knob_kind, layout_kind,
                               persist_workflow, pool_kind, prefill_workflow,
                               prefix_seed_workflow, serve_decode_workflow,
                               serve_tick_workflow, snapshot_workflow,
                               spec_kind, train_step_workflow)
from repro.engine.prefix_cache import (PrefixAnalyzer, PrefixCache,
                                       request_fingerprint)
from repro.engine.serve import (PROPOSERS, DraftProposer, NgramProposer,
                                Proposer, Request, ServeEngine, SlotPool,
                                build_slot_tick)

__all__ = ["AutoTuner", "DraftProposer", "Engine", "Job", "Knob",
           "NgramProposer", "PROPOSERS",
           "PrefixAnalyzer", "PrefixCache", "Proposer", "Request",
           "ServeEngine", "SlotPool", "TickCandidate", "accept_kind",
           "build_slot_tick", "checkpoint_workflow", "distill_draft",
           "knob_kind", "layout_kind", "persist_workflow", "pool_kind",
           "prefill_workflow", "prefix_seed_workflow",
           "request_fingerprint", "serve_decode_workflow",
           "serve_tick_workflow", "slice_draft_params", "small_draft_cfg",
           "snapshot_workflow", "spec_kind", "train_step_workflow",
           "truncated_draft_cfg"]
