"""Online Maestro: the unified engine layer.

Training and serving run as region-structured, result-aware *jobs* under one
Amber-style executor — see ``engine.engine.Engine`` (control plane + cost
book + decisions), ``engine.jobs`` (the job -> region-workflow mapping), and
``engine.serve.ServeEngine`` (continuous batching).  ``runtime.loop`` and
``runtime.serve`` are clients of this layer.
"""
from repro.engine.engine import Engine
from repro.engine.jobs import (Job, TickCandidate, accept_kind,
                               checkpoint_workflow, pool_kind,
                               serve_decode_workflow, serve_tick_workflow,
                               train_step_workflow)
from repro.engine.serve import (Request, ServeEngine, SlotPool,
                                build_slot_tick)

__all__ = ["Engine", "Job", "Request", "ServeEngine", "SlotPool",
           "TickCandidate", "accept_kind", "build_slot_tick",
           "checkpoint_workflow", "pool_kind", "serve_decode_workflow",
           "serve_tick_workflow", "train_step_workflow"]
