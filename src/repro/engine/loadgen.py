"""Scenario-diverse workload generation + the virtual-time drive harness.

ROADMAP item 5: every decision surface the engine grew — priority
arbitration, prefix admission, the spec-arm family, placement/migration —
had only ever been exercised by single-scenario smoke benches.  Benchmark-
suite work on big-data frameworks (BigBench on Hive/Spark; the Inoubli et
al. experimental survey) shows single-workload evaluation systematically
hides tail-latency and adaptivity failures; this module is the diverse,
parameterized workload source that exposes them.

Three layers, all seeded and deterministic:

* **Samplers** — arrival processes (Poisson, bursty, diurnal ramp,
  closed), heavy-tail length distributions (bounded Pareto), priority
  mixes, and prompt populations (disjoint vs shared-preamble, the latter
  exercising the prefix cache).
* **Scenarios** — a :class:`ScenarioSpec` composes samplers into a named
  workload; :data:`SCENARIOS` registers the gauntlet's families, including
  the adversarial ones (priority starvation, chunk thrash, hot-swap
  storm).  ``generate(spec, seed)`` expands a spec into a concrete request
  stream; the same (spec, seed) always yields the identical stream — the
  replay property the property tests pin.
* **Drive harness** — :func:`drive` plays a stream against a live
  :class:`~repro.engine.serve.ServeEngine` under **virtual time**: the
  clock is the engine's tick count, arrivals due at virtual tick ``t``
  are submitted before tick ``t`` runs, and idle gaps fast-forward to the
  next arrival instead of burning empty ticks.  TTFT/completion are
  recorded in virtual ticks (scheduling quality, host-speed independent)
  alongside the engine's own wall-clock marks.

The grading vocabulary (``percentile``, :class:`ServeSLO`, ``grade_slo``)
lives in :mod:`repro.core.scheduler` with the other scoring primitives;
``summarize`` here produces the metrics dict those graders consume.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import ServeSLO, percentile

__all__ = [
    "GenRequest", "ScenarioSpec", "SCENARIOS", "scenario", "generate",
    "arrival_offsets", "drive", "DriveResult", "summarize",
    "poisson_arrivals", "bursty_arrivals", "diurnal_arrivals",
    "closed_arrivals", "heavy_tail_lengths", "uniform_lengths",
]


# ------------------------------------------------------------------ stream

@dataclasses.dataclass(frozen=True)
class GenRequest:
    """One generated request: ``at`` is its arrival in virtual ticks.
    ``prompt`` is a tuple (hashable → usable as an oracle memo key)."""
    at: int
    prompt: Tuple[int, ...]
    max_new: int
    priority: str = "default"
    temperature: float = 0.0


# ---------------------------------------------------------------- arrivals
# Every sampler takes a ``numpy.random.Generator`` and returns ``n`` sorted
# integer virtual-tick offsets starting at 0.  Rates are requests/tick.

def poisson_arrivals(rng: np.random.Generator, n: int,
                     rate: float) -> np.ndarray:
    """Memoryless arrivals: exponential inter-arrival gaps at ``rate``
    requests per virtual tick, floored onto the tick grid."""
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n)
    return np.floor(np.cumsum(gaps)).astype(np.int64)


def bursty_arrivals(rng: np.random.Generator, n: int, burst: int,
                    gap: float) -> np.ndarray:
    """Bursts of ``burst`` simultaneous arrivals, burst starts separated
    by exponential gaps of mean ``gap`` ticks — the overload pattern: each
    burst lands as one queue spike the admission path must absorb."""
    n_bursts = -(-n // burst)
    starts = np.floor(np.cumsum(
        rng.exponential(max(gap, 1e-9), size=n_bursts))).astype(np.int64)
    return np.repeat(starts, burst)[:n]


def diurnal_arrivals(rng: np.random.Generator, n: int, period: float,
                     peak_rate: float, trough_rate: float) -> np.ndarray:
    """Diurnal ramp: a non-homogeneous Poisson process whose rate swings
    sinusoidally between ``trough_rate`` and ``peak_rate`` over ``period``
    ticks, sampled by thinning against the peak rate.  Exercises the
    adaptivity story: EMAs tuned during the trough meet the peak."""
    lo, hi = min(trough_rate, peak_rate), max(trough_rate, peak_rate)
    out, t = [], 0.0
    while len(out) < n:
        t += rng.exponential(1.0 / max(hi, 1e-9))
        lam = lo + (hi - lo) * 0.5 * (1 + np.sin(2 * np.pi * t / period))
        if rng.random() < lam / hi:
            out.append(int(t))
    return np.asarray(out[:n], np.int64)


def closed_arrivals(rng: np.random.Generator, n: int) -> np.ndarray:
    """Closed-loop: everything arrives at tick 0 (the classic drain-a-
    batch workload every pre-gauntlet bench measured)."""
    return np.zeros(n, np.int64)


_ARRIVALS: Dict[str, Callable[..., np.ndarray]] = {
    "poisson": poisson_arrivals,
    "bursty": bursty_arrivals,
    "diurnal": diurnal_arrivals,
    "closed": closed_arrivals,
}


def arrival_offsets(kind: str, n: int, rng: np.random.Generator,
                    **params) -> np.ndarray:
    """Dispatch by name — the hook the differential harness's arrival axis
    uses so a scenario dict stays plain data."""
    return _ARRIVALS[kind](rng, n, **params)


# ----------------------------------------------------------------- lengths

def heavy_tail_lengths(rng: np.random.Generator, n: int, lo: int, hi: int,
                       alpha: float = 1.3) -> np.ndarray:
    """Bounded Pareto lengths on [lo, hi]: most requests short, a heavy
    tail of long ones — the distribution that makes uniform chunk sizes
    and naive batching look good in the mean and terrible at p99."""
    u = rng.random(size=n)
    la, ha = float(lo) ** alpha, float(hi) ** alpha
    x = (-(u * (ha - la) - ha) / (ha * la)) ** (-1.0 / alpha)
    return np.clip(np.floor(x), lo, hi).astype(np.int64)


def uniform_lengths(rng: np.random.Generator, n: int, lo: int,
                    hi: int) -> np.ndarray:
    return rng.integers(lo, hi + 1, size=n, dtype=np.int64)


_LENGTHS = {"heavy_tail": heavy_tail_lengths, "uniform": uniform_lengths}


# --------------------------------------------------------------- scenarios

@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One named workload family.  Everything is plain data so specs can be
    replaced (``dataclasses.replace``) to miniaturize for the fast suite.

    ``events`` schedules hot control actions at virtual ticks — each entry
    ``(tick, updates)`` is applied via ``engine.update(**updates)`` just
    before that tick runs (the hot-swap-storm / knob-thrash ingredient).
    ``slos`` grades the drive (see ``scheduler.grade_slo``)."""
    name: str
    n: int                                  # requests in the stream
    arrival: str = "poisson"                # _ARRIVALS key
    arrival_params: Tuple[Tuple[str, Any], ...] = ()
    plen: str = "uniform"                   # _LENGTHS key (prompt lengths)
    plen_params: Tuple[Tuple[str, Any], ...] = (("lo", 4), ("hi", 12))
    max_new: str = "uniform"                # _LENGTHS key (response lengths)
    max_new_params: Tuple[Tuple[str, Any], ...] = (("lo", 4), ("hi", 8))
    mix: Tuple[Tuple[str, float], ...] = (("default", 1.0),)
    population: str = "disjoint"            # "disjoint" | "shared"
    n_preambles: int = 2                    # shared: distinct preambles
    preamble_frac: float = 0.5              # shared: prefix share of plen
    vocab: int = 97                         # token id range (kept tiny so
    #                                         shared prefixes actually repeat)
    events: Tuple[Tuple[int, Tuple[Tuple[str, Any], ...]], ...] = ()
    slos: Tuple[ServeSLO, ...] = ()
    description: str = ""

    def event_list(self) -> List[Tuple[int, Dict[str, Any]]]:
        return [(t, dict(kv)) for t, kv in self.events]


def generate(spec: ScenarioSpec, seed: int) -> List[GenRequest]:
    """Expand a spec into its concrete request stream.  Deterministic in
    (spec, seed): the rng is seeded from the caller's seed plus a stable
    digest of the spec name, so two scenarios sharing one suite seed still
    draw independent streams, and replay is exact."""
    tag = int.from_bytes(spec.name.encode()[:8].ljust(8, b"\0"), "little")
    rng = np.random.default_rng(np.random.SeedSequence([seed, tag]))
    at = arrival_offsets(spec.arrival, spec.n, rng,
                         **dict(spec.arrival_params))
    plens = _LENGTHS[spec.plen](rng, spec.n, **dict(spec.plen_params))
    mnews = _LENGTHS[spec.max_new](rng, spec.n,
                                   **dict(spec.max_new_params))
    names = [m[0] for m in spec.mix]
    probs = np.asarray([m[1] for m in spec.mix], np.float64)
    classes = rng.choice(len(names), size=spec.n, p=probs / probs.sum())
    preambles = [tuple(int(x) for x in rng.integers(
        1, spec.vocab, size=max(int(dict(spec.plen_params)["hi"]
                                    * spec.preamble_frac), 1)))
                 for _ in range(spec.n_preambles)]
    reqs = []
    for i in range(spec.n):
        L = int(plens[i])
        if spec.population == "shared":
            pre = preambles[int(rng.integers(0, spec.n_preambles))]
            head = pre[:max(int(L * spec.preamble_frac), 1)]
            tail = tuple(int(x) for x in rng.integers(
                1, spec.vocab, size=max(L - len(head), 0)))
            prompt = head + tail
        else:
            prompt = tuple(int(x) for x in rng.integers(1, spec.vocab,
                                                        size=L))
        reqs.append(GenRequest(at=int(at[i]), prompt=prompt,
                               max_new=int(mnews[i]),
                               priority=names[int(classes[i])]))
    reqs.sort(key=lambda r: r.at)
    return reqs


# The gauntlet's scenario families.  Sizes are bench-scale; the fast suite
# miniaturizes with ``dataclasses.replace(spec, n=...)``.  SLO thresholds
# are deliberately generous — they are regression tripwires for gross
# scheduling failures (starvation, collapse under overload), not
# performance targets; docs/STRESS_TESTS.md records the measured margins.

SCENARIOS: Dict[str, ScenarioSpec] = {}


def scenario(spec: ScenarioSpec) -> ScenarioSpec:
    assert spec.name not in SCENARIOS, f"duplicate scenario {spec.name}"
    SCENARIOS[spec.name] = spec
    return spec


scenario(ScenarioSpec(
    name="steady_poisson", n=24,
    arrival="poisson", arrival_params=(("rate", 0.5),),
    slos=(ServeSLO(p50_ttft=40, p99_ttft=160, min_goodput=0.25),),
    description="Memoryless moderate load: the baseline every other "
                "scenario's grades are read against."))

scenario(ScenarioSpec(
    name="bursty_overload", n=32,
    arrival="bursty", arrival_params=(("burst", 8), ("gap", 24.0)),
    plen="heavy_tail", plen_params=(("lo", 4), ("hi", 14), ("alpha", 1.2)),
    slos=(ServeSLO(p99_ttft=280, min_goodput=0.2, max_deferred=48),),
    description="Queue spikes over slot capacity: admission + aging under "
                "overload; goodput must not collapse between bursts."))

scenario(ScenarioSpec(
    name="heavy_tail", n=24,
    arrival="poisson", arrival_params=(("rate", 0.4),),
    plen="heavy_tail", plen_params=(("lo", 4), ("hi", 16), ("alpha", 1.1)),
    max_new="heavy_tail",
    max_new_params=(("lo", 2), ("hi", 10), ("alpha", 1.3)),
    slos=(ServeSLO(p50_ttft=48, p99_ttft=240),),
    description="Pareto prompt AND response lengths: long-tail residents "
                "must not starve short arrivals (chunked prefill test)."))

scenario(ScenarioSpec(
    name="priority_starvation", n=32,
    arrival="bursty", arrival_params=(("burst", 6), ("gap", 12.0)),
    mix=(("interactive", 0.75), ("batch", 0.25)),
    slos=(ServeSLO(scope="interactive", p50_ttft=56, p99_ttft=240),
          ServeSLO(scope="batch", max_deferred=24, p99_ttft=400)),
    description="Adversarial: heavy interactive flood against a batch "
                "trickle — the per-class aging bound must keep batch "
                "prefills from starving (max_deferred is the tripwire)."))

scenario(ScenarioSpec(
    name="shared_preamble", n=24,
    arrival="poisson", arrival_params=(("rate", 0.6),),
    population="shared", n_preambles=2, preamble_frac=0.6,
    plen_params=(("lo", 8), ("hi", 14)),
    slos=(ServeSLO(p50_ttft=40, p99_ttft=200, min_goodput=0.25),),
    description="Agent-loop population: most prompts share one of two "
                "preambles — prefix-cache admission should win here, and "
                "winning must not cost correctness or tail latency."))

scenario(ScenarioSpec(
    name="diurnal_ramp", n=28,
    arrival="diurnal",
    arrival_params=(("period", 80.0), ("peak_rate", 1.0),
                    ("trough_rate", 0.05)),
    slos=(ServeSLO(p50_ttft=48, p99_ttft=280),),
    description="Rate swings trough→peak: cost EMAs and knob choices "
                "tuned in the quiet phase meet the rush."))

scenario(ScenarioSpec(
    name="hot_swap_storm", n=24,
    arrival="poisson", arrival_params=(("rate", 0.5),),
    events=tuple((t, (("params_version", 1000 + t),))
                 for t in range(8, 200, 16)),
    slos=(ServeSLO(p99_ttft=280, max_dropped=0),),
    description="Weight-publish storm: a params_version bump lands every "
                "16 ticks mid-flight — zero drops, stale results must "
                "never serve, tails must stay bounded."))

scenario(ScenarioSpec(
    name="chunk_thrash", n=24,
    arrival="bursty", arrival_params=(("burst", 4), ("gap", 10.0)),
    plen="heavy_tail", plen_params=(("lo", 4), ("hi", 14), ("alpha", 1.2)),
    events=tuple((t, (("prefill_chunk", 1 if (t // 12) % 2 else 16),
                      ("spec_decode", bool((t // 12) % 2))))
                 for t in range(6, 200, 12)),
    slos=(ServeSLO(p99_ttft=320, max_dropped=0),),
    description="Adversarial knob thrash: prefill_chunk and spec_decode "
                "flip every 12 ticks under bursty load — hot updates must "
                "stay safe (no overruns, no drops) however ill-timed."))


# ------------------------------------------------------------------- drive

@dataclasses.dataclass
class ReqTrace:
    """Virtual-tick life of one request, paired with the engine's own
    wall-clock marks after the drive completes."""
    gen: GenRequest
    req: Any                                # live engine Request
    t_submit: int = 0
    t_first: Optional[int] = None
    t_done: Optional[int] = None

    @property
    def ttft(self) -> float:
        return (float("inf") if self.t_first is None
                else float(self.t_first - self.t_submit))


@dataclasses.dataclass
class DriveResult:
    traces: List[ReqTrace]
    ticks: int                              # virtual ticks consumed
    idle_skipped: int                       # ticks fast-forwarded over
    wall_s: float
    tokens_out: int
    events_applied: int

    def outputs(self) -> List[np.ndarray]:
        return [t.req.output() for t in self.traces]


def drive(engine, reqs: Sequence[GenRequest], max_ticks: int = 5000,
          events: Sequence[Tuple[int, Dict[str, Any]]] = (),
          submit: Optional[Callable[..., Any]] = None) -> DriveResult:
    """Play a generated stream against a live engine under virtual time.

    The virtual clock is the engine tick count ``t``.  Before tick ``t``
    runs, every request with ``at <= t`` is submitted (through ``submit``
    when given — the ``BatchedServer.submit`` entry point — else
    ``engine.submit``) and every scheduled event with ``tick <= t`` is
    applied via ``engine.update``.  When the engine is fully idle and work
    is still coming, the clock fast-forwards to the next arrival instead
    of spinning empty ticks, so sparse tails cost nothing.

    First-token/completion are detected host-side between ticks (a token
    list turning non-empty / ``t_done`` set), so TTFT lands in virtual
    ticks — the deterministic-across-hosts unit the SLO grades use."""
    import time
    reqs = sorted(reqs, key=lambda r: r.at)
    events = sorted(events, key=lambda e: e[0])
    sub = submit or engine.submit
    traces: List[ReqTrace] = []
    pending = list(reqs)
    pend_ev = list(events)
    live: List[ReqTrace] = []
    t = 0
    idle_skipped = 0
    n_ev = 0
    t0 = time.perf_counter()
    for _ in range(max_ticks):
        while pend_ev and pend_ev[0][0] <= t:
            engine.update(**pend_ev[0][1])
            pend_ev.pop(0)
            n_ev += 1
        while pending and pending[0].at <= t:
            g = pending.pop(0)
            r = sub(np.asarray(g.prompt, np.int32), g.max_new,
                    g.temperature, priority=g.priority)
            tr = ReqTrace(gen=g, req=r, t_submit=t)
            traces.append(tr)
            live.append(tr)
        if not traces and not pending and not pend_ev:
            break
        alive = engine.tick()
        for tr in list(live):
            if tr.t_first is None and (tr.req.tokens
                                       or tr.req.t_first is not None):
                tr.t_first = t
            if tr.req.done.is_set():
                tr.t_done = tr.t_done if tr.t_done is not None else t
                live.remove(tr)
        t += 1
        if not alive:
            break
        if not live and not engine.queue:
            if pending or pend_ev:
                nxt = min(([pending[0].at] if pending else [])
                          + ([pend_ev[0][0]] if pend_ev else []))
                if nxt > t:
                    idle_skipped += nxt - t
                    t = nxt
            else:
                break
    return DriveResult(traces=traces, ticks=t, idle_skipped=idle_skipped,
                       wall_s=time.perf_counter() - t0,
                       tokens_out=sum(len(tr.req.tokens)
                                      for tr in traces),
                       events_applied=n_ev)


# --------------------------------------------------------------- summarize

def summarize(res: DriveResult) -> Dict[str, float]:
    """Flatten a drive into the metrics dict ``scheduler.grade_slo``
    consumes: pooled ``p50_ttft``/``p99_ttft``/``goodput``/``max_deferred``
    /``dropped`` plus the same per priority class under ``<cls>/`` keys.
    Goodput counts only tokens of COMPLETED requests over busy (non-fast-
    forwarded) virtual ticks — half-finished work is not goodput."""
    busy = max(res.ticks - res.idle_skipped, 1)
    done = [tr for tr in res.traces if tr.t_done is not None]
    out: Dict[str, float] = {
        "n": float(len(res.traces)),
        "completed": float(len(done)),
        "dropped": float(sum(1 for tr in res.traces
                             if tr.t_done is None)),
        "goodput": sum(min(len(tr.req.tokens), tr.req.max_new)
                       for tr in done) / busy,
        "ticks": float(res.ticks),
        "busy_ticks": float(busy),
        "wall_s": res.wall_s,
    }
    by_cls: Dict[str, List[ReqTrace]] = {}
    for tr in res.traces:
        by_cls.setdefault(tr.gen.priority, []).append(tr)
    scopes: List[Tuple[Optional[str], List[ReqTrace]]] = \
        [(None, res.traces)] + sorted(by_cls.items())
    for scope, trs in scopes:
        pre = f"{scope}/" if scope else ""
        ttfts = [tr.ttft for tr in trs if tr.t_first is not None]
        out[pre + "p50_ttft"] = percentile(ttfts, 50)
        out[pre + "p99_ttft"] = percentile(ttfts, 99)
        out[pre + "max_deferred"] = float(max(
            (tr.req.max_deferred for tr in trs), default=0))
        if scope:
            out[pre + "dropped"] = float(sum(1 for tr in trs
                                             if tr.t_done is None))
            out[pre + "goodput"] = sum(
                min(len(tr.req.tokens), tr.req.max_new)
                for tr in trs if tr.t_done is not None) / busy
    return out
