"""Cross-request prefix cache + result-aware request caching.

The result-aware thesis applied to serving state: the engine should price
what it *already knows* against what it would *recompute*.  At serving
scale, what it already knows is the KV/SSM state of every prefix it has
ever prefilled — millions of users share system prompts and few-shot
preambles, yet a naive engine re-prefills every request from token 0.
This module makes that intermediate state a first-class, reusable artifact
(the Whiz/F² position) behind two data structures:

* :class:`PrefixCache` — a radix tree over **committed token sequences**.
  A node's path is a token prefix; a node may own a *snapshot*: one
  donated-pool slot row (every cache leaf — KV rows, recurrent/conv state,
  n-gram table — plus the frozen ``pos``) captured at a tick boundary where
  the slot had consumed exactly that prefix.  ``longest_match`` finds the
  deepest snapshotted ancestor of a new prompt, and the serving engine
  seeds the joining slot from it with one jitted batched row write, so
  prefill cost drops from ``O(len(prompt))`` to ``O(unshared suffix)``.
  Snapshots are **bit-identical** to recomputation: the tick scans
  ``lm.decode_step`` token by token, so the state after P tokens does not
  depend on chunking, slot index, or which pool ran it — seeding is
  replay, not approximation.

* an **exact-hit result cache** — finished greedy outputs keyed by a
  canonical request fingerprint (:func:`request_fingerprint`: tokens +
  max_new + temperature + params-version).  An exact hit skips the slot
  pools entirely.  Greedy decoding is prefix-stable, so a cached response
  also answers any shorter ``max_new`` for the same prompt by truncation —
  result-awareness, not just memoization.  Sampled requests
  (temperature > 0) never store and never hit: their outputs are draws,
  not facts.

Whether a matched prefix is *used* is not a heuristic — it is a measured
Maestro decision (``Engine.choose_prefix_admission``): the engine scores a
``jobs.prefix_seed_workflow`` (copy the cached row, then prefill only the
suffix) against ``jobs.prefill_workflow`` (recompute from token 0) under
first-response time, with the copy cost and per-token prefill cost coming
from per-pool CostBook EMAs.

Memory safety: the tree is capacity-bounded (``cfg.serve`` knobs) with LRU
eviction over snapshot bytes; a node is *not evictable* while a request
seeded from it is in flight (ref-count) or while the workload analyzer has
pinned it.  :class:`PrefixAnalyzer` mines the recent request history for
hot prefixes worth pinning — the serving analog of a materialized-view
advisor: canonicalize → fingerprint → reuse → suggest materializations.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, OrderedDict, deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def to_host(tree):
    """Normalize a pytree of device arrays to **host numpy** — the
    placement-portable form every prefix snapshot is stored in.  A host
    snapshot is uncommitted, so a later seed write follows the *destination*
    pool's placement regardless of which device group captured it; it also
    survives the capturing pool being drained away.  ``device_get`` pulls
    across any sharding; ``np.asarray`` pins the leaves as plain numpy."""
    return jax.tree.map(np.asarray, jax.device_get(tree))


def request_fingerprint(tokens, max_new: int, temperature: float,
                        params_version: int) -> Optional[tuple]:
    """Canonical identity of a request's *answer*, or None when the answer
    is not a deterministic function of the request.

    Canonicalization rules (unit-pinned in tests/test_prefix_cache.py):

    * tokens are canonicalized to a tuple of python ints — the same prompt
      hashes identically whether it arrived as list, np.int32 or np.int64;
    * every ``temperature <= 0`` means greedy and collapses to ``0.0``, so
      ``-1.0`` and ``0.0`` share one cache line;
    * ``temperature > 0`` returns **None** — sampled outputs are draws from
      a distribution, not cacheable facts, so they must MISS;
    * ``params_version`` is part of the key — a hot weight swap must not
      serve answers computed under the old weights.

    ``max_new`` is NOT part of the returned key: the result cache stores
    the longest known greedy continuation per (tokens, params_version) and
    answers shorter requests by truncation (greedy is prefix-stable).
    """
    if temperature > 0:
        return None
    return (tuple(int(t) for t in tokens), int(params_version))


@dataclasses.dataclass
class _Node:
    """One radix-tree node.  ``edge`` is the compressed token run from the
    parent; ``depth`` is the total path length (tokens from root).  A node
    with ``snapshot is not None`` is a reusable prefix state."""
    edge: Tuple[int, ...]
    depth: int
    parent: Optional["_Node"] = None
    children: Dict[int, "_Node"] = dataclasses.field(default_factory=dict)
    snapshot: Any = None          # pool-row pytree (host numpy) or None
    pos: int = 0                  # tokens consumed by the snapshot ( == depth)
    version: int = 0              # params_version the snapshot was captured
    #                               under: state computed by OLD weights must
    #                               never seed a slot serving NEW weights
    last_use: int = 0             # LRU clock value of the last hit/insert
    hits: int = 0
    refs: int = 0                 # in-flight requests seeded from this node
    pinned: bool = False          # analyzer-protected from eviction


class PrefixCache:
    """Radix tree of snapshotted prefixes + the exact-hit result cache.

    Pure host-side bookkeeping: device work (row gather for snapshots, row
    scatter for seeding) stays in the serving engine's jitted paths — this
    class only holds references to the captured pytrees and decides what to
    keep.  ``capacity`` bounds the number of live snapshots (the unit the
    donated pools actually pay for); the result cache is bounded separately
    in entries.  Not thread-safe by design: the serving engine mutates it
    between ticks only, like every other piece of scheduler state.
    """

    def __init__(self, capacity: int = 128, min_len: int = 4,
                 result_entries: int = 256):
        assert capacity >= 1 and min_len >= 1 and result_entries >= 0
        self.capacity = capacity
        self.min_len = min_len
        self.result_entries = result_entries
        self.root = _Node(edge=(), depth=0)
        self._clock = 0
        self._snapshots = 0
        # counters surfaced through ServeEngine._inspect("prefix_cache")
        self.hits = 0               # longest_match found a usable snapshot
        self.misses = 0             # no snapshot (or too short) for a prompt
        self.evictions = 0          # snapshots dropped by the LRU bound
        self.result_hits = 0
        self.result_misses = 0
        self.tokens_avoided = 0     # prefill tokens skipped via seeding
        self.seeded = 0             # requests admitted through a seed write
        self.seed_declined = 0      # matches the engine priced out
        # result cache: fingerprint -> (max_new_known, tokens tuple); LRU
        self._results: "OrderedDict[tuple, Tuple[int, Tuple[int, ...]]]" = \
            OrderedDict()
        self._pinned_paths: set = set()

    # ------------------------------------------------------------ radix tree
    def _tick_clock(self) -> int:
        self._clock += 1
        return self._clock

    def longest_match(self, tokens, limit: Optional[int] = None,
                      version: Optional[int] = None) -> Optional[_Node]:
        """Deepest snapshotted node whose path is a prefix of ``tokens``,
        at most ``limit`` tokens deep (the serving engine passes
        ``len(prompt) - 1``: at least one real prompt token must remain to
        produce the first output logits).  ``version`` (not None) restricts
        matches to snapshots captured under that params version — after a
        hot weight swap, old-version KV state must never seed a slot that
        will decode under the new weights (it would replay stale state and
        break greedy bit-identicality).  Touches the LRU clock of the
        returned node only — intermediate structural nodes carry no state
        worth aging."""
        toks = tuple(int(t) for t in tokens)
        limit = len(toks) if limit is None else min(limit, len(toks))
        node, i, best = self.root, 0, None
        while i < limit:
            child = node.children.get(toks[i])
            if child is None:
                break
            edge = child.edge
            if child.depth > limit or \
                    toks[i:i + len(edge)] != edge:
                break
            node, i = child, child.depth
            if node.snapshot is not None and node.depth >= self.min_len \
                    and (version is None or node.version == version):
                best = node
        if best is None:
            self.misses += 1
            return None
        best.last_use = self._tick_clock()
        best.hits += 1
        self.hits += 1
        return best

    def lookup(self, tokens) -> Optional[_Node]:
        """Exact-path node (snapshot or not), no counters touched — the
        snapshot-dedupe path: the engine skips re-capturing a prefix whose
        node already owns a snapshot."""
        toks = tuple(int(t) for t in tokens)
        node, i = self.root, 0
        while i < len(toks):
            child = node.children.get(toks[i])
            if child is None or toks[i:i + len(child.edge)] != child.edge:
                return None
            node, i = child, child.depth
        return node if node.depth == len(toks) else None

    def _split(self, node: _Node, at: int) -> _Node:
        """Split ``node``'s edge after ``at`` tokens; returns the new
        intermediate parent (snapshotless — state stays with the deep
        half, whose path is unchanged)."""
        assert 0 < at < len(node.edge)
        upper = _Node(edge=node.edge[:at],
                      depth=node.depth - len(node.edge) + at,
                      parent=node.parent)
        node.parent.children[upper.edge[0]] = upper
        node.edge = node.edge[at:]
        node.parent = upper
        upper.children[node.edge[0]] = node
        return upper

    def insert(self, tokens, snapshot=None, version: int = 0
               ) -> Optional[_Node]:
        """Commit a token path into the tree, attaching ``snapshot`` (a
        captured pool-row pytree, normalized to host numpy via
        :func:`to_host` by the capturing engine) at its end, tagged with the
        ``version`` of the params it was computed under.  Paths shorter than
        ``min_len`` are not worth a node; re-inserting an existing path
        refreshes its snapshot/version/LRU slot.  Returns the node (None
        when the path was rejected as too short)."""
        toks = tuple(int(t) for t in tokens)
        if len(toks) < self.min_len:
            return None
        node, i = self.root, 0
        while i < len(toks):
            child = node.children.get(toks[i])
            if child is None:
                child = _Node(edge=toks[i:], depth=len(toks), parent=node)
                node.children[toks[i]] = child
                node, i = child, len(toks)
                break
            edge = child.edge
            common = 0
            while common < len(edge) and i + common < len(toks) and \
                    edge[common] == toks[i + common]:
                common += 1
            if common < len(edge):
                upper = self._split(child, common)
                if i + common == len(toks):
                    node, i = upper, len(toks)
                    break
                rest = _Node(edge=toks[i + common:], depth=len(toks),
                             parent=upper)
                upper.children[rest.edge[0]] = rest
                node, i = rest, len(toks)
                break
            node, i = child, child.depth
        assert node.depth == len(toks)
        if snapshot is not None:
            if node.snapshot is None:
                self._snapshots += 1
            node.snapshot = snapshot
            node.pos = len(toks)
            node.version = int(version)
            node.last_use = self._tick_clock()
            if toks in self._pinned_paths:
                node.pinned = True
            self._enforce_capacity()
        return node

    def acquire(self, node: _Node) -> None:
        node.refs += 1

    def release(self, node: _Node) -> None:
        assert node.refs > 0, "release without acquire"
        node.refs -= 1

    def _snapshot_nodes(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n.snapshot is not None:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def _prune(self, node: _Node) -> None:
        """Remove snapshotless leaf chains so evicted paths do not leave
        structural litter behind."""
        while (node is not self.root and node.snapshot is None
               and not node.children):
            parent = node.parent
            del parent.children[node.edge[0]]
            node = parent

    def _enforce_capacity(self) -> None:
        """LRU eviction over snapshots.  Pinned nodes and nodes with live
        refs are not evictable — if everything is protected the cache runs
        over capacity rather than corrupting an in-flight seed (the bound
        is restored as soon as refs drain)."""
        while self._snapshots > self.capacity:
            victims = [n for n in self._snapshot_nodes()
                       if n.refs == 0 and not n.pinned]
            if not victims:
                return
            victim = min(victims, key=lambda n: n.last_use)
            victim.snapshot = None
            self._snapshots -= 1
            self.evictions += 1
            self._prune(victim)

    def flush_versions(self, keep: int) -> int:
        """Drop every snapshot whose version differs from ``keep`` — the
        weight-publish hook: after a hot swap nothing captured under the old
        weights can ever match again (``longest_match`` filters by version),
        so the bytes are pure waste.  Nodes with live refs keep their
        snapshot until the in-flight seed drains (the seeded request itself
        joined under the old version and is version-gated out of
        re-snapshotting).  Returns the number of snapshots dropped."""
        dropped = 0
        for n in self._snapshot_nodes():
            if n.version != keep and n.refs == 0:
                n.snapshot = None
                self._snapshots -= 1
                self.evictions += 1
                dropped += 1
                self._prune(n)
        return dropped

    def pin(self, tokens) -> bool:
        """Protect a prefix from eviction (analyzer-driven).  Pins the node
        if it exists now and remembers the path so a later snapshot of it
        is born pinned."""
        toks = tuple(int(t) for t in tokens)
        self._pinned_paths.add(toks)
        node = self.lookup(toks)
        if node is not None:
            node.pinned = True
            return True
        return False

    @property
    def pinned(self) -> int:
        return sum(1 for n in self._snapshot_nodes() if n.pinned)

    @property
    def snapshots(self) -> int:
        return self._snapshots

    # ---------------------------------------------------------- result cache
    def result_lookup(self, tokens, max_new: int, temperature: float,
                      params_version: int) -> Optional[List[int]]:
        """Exact-hit answer for a request, or None.  A stored continuation
        longer than ``max_new`` answers by truncation (greedy is
        prefix-stable); a shorter one is NOT enough and misses."""
        fp = request_fingerprint(tokens, max_new, temperature,
                                 params_version)
        if fp is None or self.result_entries == 0:
            self.result_misses += 1
            return None
        entry = self._results.get(fp)
        if entry is None or entry[0] < max_new:
            self.result_misses += 1
            return None
        self._results.move_to_end(fp)
        self.result_hits += 1
        return list(entry[1][:max_new])

    def result_store(self, tokens, max_new: int, temperature: float,
                     params_version: int, output) -> bool:
        """Record a finished request's output.  Only deterministic
        (greedy) results store; a longer continuation for the same
        fingerprint replaces a shorter one."""
        fp = request_fingerprint(tokens, max_new, temperature,
                                 params_version)
        if fp is None or self.result_entries == 0:
            return False
        out = tuple(int(t) for t in output)
        prev = self._results.get(fp)
        if prev is not None and prev[0] >= len(out):
            self._results.move_to_end(fp)
            return False
        self._results[fp] = (len(out), out)
        self._results.move_to_end(fp)
        while len(self._results) > self.result_entries:
            self._results.popitem(last=False)
        return True

    # -------------------------------------------------------------- counters
    def stats(self) -> Dict[str, Any]:
        return {"enabled": True, "nodes": len(self._snapshot_nodes()),
                "snapshots": self._snapshots, "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "pinned": self.pinned,
                "seeded": self.seeded, "seed_declined": self.seed_declined,
                "tokens_avoided": self.tokens_avoided,
                "result_hits": self.result_hits,
                "result_misses": self.result_misses,
                "result_entries": len(self._results)}


class PrefixAnalyzer:
    """Workload analyzer: mines the recent request history for hot shared
    prefixes worth pinning in the :class:`PrefixCache`.

    Canonicalize → fingerprint → count → suggest: each submitted prompt is
    truncated to candidate prefix lengths on a coarse grid (powers of two
    of ``min_len`` — the same boundaries prefill-tick snapshots land on,
    so suggestions map onto nodes the tree can actually hold), counted in a
    bounded sliding window, and any prefix seen at least ``pin_count``
    times is reported hot.  The serving engine pins the suggestions, which
    exempts those snapshots from LRU eviction — the serving analog of a
    materialized-view advisor promoting a hot subplan."""

    def __init__(self, min_len: int = 4, pin_count: int = 3,
                 history: int = 512):
        self.min_len = max(min_len, 1)
        self.pin_count = max(pin_count, 1)
        self.history = max(history, 1)
        self._window: deque = deque()
        self._counts: Counter = Counter()

    def _grid(self, plen: int):
        L = self.min_len
        while L <= plen - 1:          # a seed must leave >= 1 prompt token
            yield L
            L *= 2

    def record(self, tokens) -> None:
        toks = tuple(int(t) for t in tokens)
        prefixes = [toks[:L] for L in self._grid(len(toks))]
        self._window.append(prefixes)
        for p in prefixes:
            self._counts[p] += 1
        while len(self._window) > self.history:
            for p in self._window.popleft():
                self._counts[p] -= 1
                if self._counts[p] <= 0:
                    del self._counts[p]

    def hot_prefixes(self) -> List[Tuple[int, ...]]:
        """Hot prefixes, longest first — pinning the longest shared run
        dominates pinning its own prefixes (a match at depth d covers every
        shallower boundary)."""
        hot = [p for p, c in self._counts.items() if c >= self.pin_count]
        hot.sort(key=len, reverse=True)
        return hot
