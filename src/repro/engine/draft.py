"""Draft models for the speculative serve tick (ROADMAP item 4b).

The speculative tick's accept/commit machinery is proposer-agnostic: any
source of a ``spec_len``-token draft chain works, because the target model
verifies every position and the carried ``valid`` mask freezes state past
the first mismatch (``engine.serve.build_slot_tick``).  This module supplies
the *draft-model* proposer family — a second, much smaller parameter set
that decodes ahead of the target:

* **truncated self-draft** (``truncated_draft_cfg`` + ``slice_draft_params``)
  — the serve model's own first ``cfg.serve.draft_layers`` blocks plus the
  shared embedding/head.  Zero extra weights to ship or train; its agreement
  with the full model is a property of the trained checkpoint (layer
  truncation approximates a trained residual stack, so on the random-init
  smoke models used in tests its acceptance is ~0 — which the harness uses
  deliberately to exercise the all-reject path).

* **independent small draft** (``small_draft_cfg``) — a separately-specified
  tiny config over the same vocab (default: 1 block of the target's leading
  pattern type at d_model 32 — ~7% of the smoke target's per-step cost).
  ``distill_draft`` trains it by cross-entropy on the target's own greedy
  streams (the draft's only job is to predict the target's argmax, so the
  target is the perfect teacher and a few hundred AdamW steps on a few
  streams reach >0.9 argmax agreement at smoke scale).

Either way the draft's correctness burden is zero: a wrong, stale, or
mid-stream hot-swapped draft can only lower acceptance, never change
output tokens — the target's argmax is what commits.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.optim import adamw

# model param groups that are not stacked per-block and are shared with any
# truncation of the layer pattern (the "shared head" of a self-draft)
_SHARED_KEYS = ("embed", "final_ln", "lm_head")


def truncated_draft_cfg(cfg: ArchConfig,
                        layers: Optional[int] = None) -> ArchConfig:
    """The self-draft config: the first ``layers`` blocks of ``cfg``'s
    pattern (default ``cfg.serve.draft_layers``) with every dimension kept —
    the draft IS the target's own bottom, so its params are slices of the
    target's (``slice_draft_params``) and a weight update republishes both
    from one tree."""
    layers = int(cfg.serve.draft_layers if layers is None else layers)
    assert 1 <= layers < cfg.num_layers, \
        f"draft_layers={layers} must be in [1, {cfg.num_layers})"
    pat = cfg.pattern[:layers]
    assert "enc" not in pat and "dec" not in pat, \
        "self-draft only truncates decoder-only patterns"
    return dataclasses.replace(cfg, name=f"{cfg.name}-selfdraft{layers}",
                               num_layers=layers, layer_pattern=pat,
                               enc_layers=0)


def slice_draft_params(params, cfg: ArchConfig,
                       draft_cfg: ArchConfig):
    """Materialize the truncated self-draft's parameter tree from the target
    tree: per-block-type stacks keep their first ``count-in-prefix`` rows
    (pattern order is preserved by truncation, so the prefix's occurrences
    of a type are exactly the leading rows of its stack), shared-head groups
    are reused as-is.  Returns new arrays (``lax.slice``), so donating or
    updating the target tree cannot alias the draft."""
    counts: dict = {}
    for t in draft_cfg.pattern:
        counts[t] = counts.get(t, 0) + 1
    out = {k: params[k] for k in _SHARED_KEYS if k in params}
    for t, n in counts.items():
        if t == "shared_attn":
            out[t] = params[t]             # single shared copy, not stacked
            continue
        out[t] = jax.tree.map(
            lambda x: jax.lax.slice_in_dim(x, 0, n), params[t])
    return out


def small_draft_cfg(cfg: ArchConfig, layers: int = 1, d_model: int = 32,
                    n_heads: int = 2) -> ArchConfig:
    """An independently-sized draft config over the target's vocab: the
    leading ``layers`` entries of the target's pattern at a much smaller
    width (the smoke default is ~7% of the target's per-decode-step cost,
    measured).  Pair with :func:`distill_draft` or externally-trained
    weights."""
    pat = cfg.pattern[:layers]
    return dataclasses.replace(
        cfg, name=f"{cfg.name}-draft{layers}x{d_model}",
        num_layers=layers, layer_pattern=pat, d_model=d_model,
        n_heads=n_heads, n_kv_heads=1, head_dim=d_model // n_heads,
        d_ff=2 * d_model, enc_layers=0)


def greedy_streams(cfg: ArchConfig, params,
                   prompts: Sequence[np.ndarray], max_new: int = 64,
                   max_len: int = 160) -> List[np.ndarray]:
    """Teacher streams for distillation: each prompt plus the target's
    greedy continuation, rolled out one jitted batched scan (prompts must
    share one length)."""
    P = len(prompts[0])
    assert all(len(p) == P for p in prompts), "prompts must share a length"
    batch = jnp.asarray(np.stack(prompts), jnp.int32)         # [B, P]

    def roll(params, toks):
        state = lm.init_cache(cfg, toks.shape[0], max_len)

        def pre(st, t):
            logits, st = lm.decode_step(params, st, t[:, None], cfg)
            return st, logits

        st, pre_logits = jax.lax.scan(pre, state, toks.T)

        def dec(carry, _):
            st, logits = carry
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)    # [B]
            logits, st = lm.decode_step(params, st, nxt[:, None], cfg)
            return (st, logits), nxt

        _, out = jax.lax.scan(dec, (st, pre_logits[-1]), None,
                              length=max_new)
        return out.T                                          # [B, max_new]

    gen = np.asarray(jax.jit(roll)(params, batch))
    return [np.concatenate([np.asarray(p, np.int32), g])
            for p, g in zip(prompts, gen)]


def distill_draft(cfg: ArchConfig, params, draft_cfg: ArchConfig,
                  prompts: Sequence[np.ndarray], max_new: int = 64,
                  steps: int = 400, batch: int = 16, seq: int = 24,
                  stride: int = 4, lr: float = 3e-3, seed: int = 7,
                  max_len: int = 160):
    """Train a draft to imitate the target's greedy stream (cross-entropy on
    next-token over windows of the teacher streams) and return its params.

    This is deliberately cheap — a few seconds at smoke scale — because the
    draft only has to match the target's *argmax on its own traffic*, not
    model language: measured on the smoke config, ~400 steps on 7 streams
    reach 0.94-1.0 argmax agreement.  Serving keeps running while a newer
    draft distills; ``ServeEngine`` republishes it mid-stream via
    ``update(draft_params=...)`` without dropping requests."""
    streams = greedy_streams(cfg, params, prompts, max_new, max_len)
    xs, ys = [], []
    for st in streams:
        arr = np.asarray(st, np.int32)
        for i in range(0, len(arr) - seq, stride):
            xs.append(arr[i:i + seq])
            ys.append(arr[i + 1:i + seq + 1])
    X, Y = np.stack(xs), np.stack(ys)

    dparams = lm.init(draft_cfg, jax.random.PRNGKey(seed))
    ocfg = adamw.AdamWCfg(lr=lr, warmup_steps=max(steps // 20, 1),
                          total_steps=steps, weight_decay=0.0)
    ostate = adamw.init(dparams)

    def loss_fn(p, x, y):
        logits, _ = lm.forward(p, {"tokens": x}, draft_cfg)
        ll = jax.nn.log_softmax(logits, -1)
        return -jnp.take_along_axis(ll, y[..., None], -1).mean()

    @jax.jit
    def train_step(p, o, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o, _ = adamw.apply(p, g, o, ocfg)
        return p, o

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, len(X), batch)
        dparams, ostate = train_step(dparams, ostate,
                                     jnp.asarray(X[idx]), jnp.asarray(Y[idx]))
    return dparams
