"""Architecture and shape configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``; heterogeneous
layer stacks (gemma3 local:global, zamba2 mamba+shared-attn) are expressed via
``layer_pattern`` — a tuple of block-type names, one per layer.  Block types:

  "attn"        full-attention decoder block (causal)
  "local"       sliding-window attention decoder block (window = cfg.window)
  "moe"         attention + MoE-FFN decoder block
  "rwkv"        RWKV6 block (time-mix + channel-mix)
  "mamba"       Mamba2 block
  "shared_attn" attention+MLP block whose weights are SHARED across all its
                occurrences (zamba2)
  "enc"         bidirectional encoder block (whisper)
  "dec"         decoder block with cross-attention (whisper)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    # Reshape integration: spare physical expert slots available as helpers.
    spare_slots: int = 2
    # max replicas a single (hot) logical expert may be split across (SBR).
    max_replicas: int = 4
    # route via the fused Pallas gating kernel (softmax + top-k + load
    # histogram in one pass); interpret-mode fallback off-TPU.
    fused_gating: bool = False
    # dispatch/combine via the fused Pallas MoE dispatch kernel family
    # (in-segment rank + capacity mask + bucketed scatter in one kernel,
    # weighted-gather combine with a custom VJP); off-TPU the same fused
    # algorithm runs as vectorized jnp (kernels/moe_dispatch/ref.py).
    # Drop decisions and Reshape load metrics are bit-identical to the
    # XLA argsort/searchsorted/scatter path.
    fused_dispatch: bool = False


@dataclasses.dataclass(frozen=True)
class PriorityClass:
    """One serve traffic class in the weighted-FRT objective.

    ``weight`` scales the class's claim on first-response time: the engine
    scores each candidate tick as FRT divided by the summed weight of the
    requests the tick advances, so a weight-4 class wins the arbitration
    against a weight-1 class whenever their raw FRTs are within 4x of each
    other.  ``max_defer`` is the class's aging bound — the maximum number of
    scheduled ticks an *admitted* prefill of this class may sit out before
    the engine is forced to run its prefill, whatever the weighted scores
    say.  Starvation of a low-weight class is therefore bounded by
    construction (regression-tested in tests/test_serve_priority.py)."""
    name: str = "default"
    weight: float = 1.0
    max_defer: int = 4


@dataclasses.dataclass(frozen=True)
class ServeCfg:
    """Serve-side engine knobs (ServeEngine).

    Speculative in-tick decoding: a per-slot n-gram suffix-hash table (living
    in the donated slot pool, no host round-trip) proposes up to ``spec_len``
    tokens per decode tick; the target model verifies them in the same
    chunk-scan dispatch and an in-jit acceptance mask commits the longest
    accepted prefix, so greedy outputs stay bit-identical to plain decode.
    Whether a tick runs the speculative or the plain arm is an *engine*
    decision made from the measured per-pool acceptance-rate EMA
    (``Engine.choose_serve_tick``).

    Priority classes: requests carry a ``priority`` naming one entry of
    ``classes``; the engine arbitrates candidate ticks across every slot
    pool under weighted FRT with per-class aging bounds (see
    :class:`PriorityClass`).  The default single-entry table reproduces the
    pre-priority scheduler exactly."""
    # max tokens proposed+verified per speculative tick (the verify-scan
    # length); <= 1 disables the speculative arm entirely.
    spec_len: int = 4
    # suffix-hash table entries per slot (power of two).  Collisions only
    # produce bad drafts — they cost acceptance, never correctness.
    spec_table: int = 512
    # n-gram context length (tokens hashed to index the table).
    spec_ctx: int = 2
    # truncated self-draft depth: ServeEngine(draft="self") builds the draft
    # proposer from the serve model's own first ``draft_layers`` blocks plus
    # the shared embedding/head (no extra weights to ship).  An independent
    # small draft is passed explicitly via draft_cfg/draft_params instead.
    draft_layers: int = 2
    # priority traffic classes, in declaration order; the FIRST entry is the
    # default class for requests submitted without an explicit priority.
    classes: Tuple[PriorityClass, ...] = (PriorityClass(),)

    # Cross-request prefix cache (ServeEngine(prefix_cache=True)): a radix
    # tree over committed token prefixes whose nodes snapshot donated-pool
    # slot rows, so a joining request that shares a cached prefix seeds its
    # cache state instead of re-prefilling, plus an exact-hit result cache
    # over finished greedy outputs.  Whether a match is used is a measured
    # engine decision (Engine.choose_prefix_admission), not a heuristic.
    # max live prefix snapshots (LRU-evicted beyond this; pinned and
    # in-flight-referenced snapshots are not evictable).
    prefix_cache_nodes: int = 128
    # shortest prefix worth snapshotting/seeding: below this the row copy
    # costs more than the prefill it would save.
    prefix_min_len: int = 4
    # exact-hit result-cache entries (0 disables the result cache).
    result_cache_entries: int = 256
    # also snapshot a request's full committed path (prompt + generated)
    # into the tree when it completes ("commit extends the tree").  Default
    # off: the per-evict row copy only pays off on agent-loop workloads
    # where one response is the next request's prompt prefix.
    snapshot_on_evict: bool = False
    # workload analyzer: a prefix seen >= pin_count times inside the
    # sliding history window is pinned against eviction.
    prefix_pin_count: int = 3
    prefix_history: int = 512
    # on a hot weight publish (ServeEngine.update(params=...) or a bare
    # params_version bump) drop prefix snapshots captured under any other
    # version: they can never match again (longest_match filters by
    # version), so keeping them is pure memory waste.  False keeps them —
    # only useful for workloads that flip back and forth between versions.
    flush_prefix_on_publish: bool = True

    # Device placement (ServeEngine(placements={pool: mesh})): each slot
    # pool may own a real device group; params are replicated (or
    # tensor-parallel at pool_tp > 1) on the pool's mesh and the donated
    # pool state lives there too, so pools on disjoint devices decode
    # concurrently.  tp=1 is the bit-identicality-preserving default — a
    # split matmul reduction reorders float adds.
    pool_tp: int = 1
    # co-dispatch decode ticks for OTHER placed pools in the same
    # scheduling round (async dispatch overlaps them on disjoint devices).
    # Inert without placements; the arbitration winner is unchanged.
    parallel_ticks: bool = True
    # max in-flight slots migrated per pool per drain step: bounds the
    # per-tick migration stall a live drain_pool() injects.
    migrate_batch: int = 4


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    state_size: int = 64
    head_dim: int = 64
    conv_kernel: int = 4
    expand: int = 2
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    layer_pattern: Tuple[str, ...] = ()   # () -> ("attn",) * num_layers  (or moe)
    window: int = 1024               # sliding window for "local" blocks
    rope_theta: float = 10000.0
    mrope: bool = False              # qwen2-vl M-RoPE (3-section rotary)
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    serve: ServeCfg = dataclasses.field(default_factory=ServeCfg)
    # encoder (whisper): encoder layer count + source length of frame embeddings
    enc_layers: int = 0
    enc_seq: int = 1500
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # activation recompute policy: chosen by the Maestro materialization pass,
    # overridable per-launch.  One of: "none", "full", "dots".
    remat: str = "full"
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern:
            assert len(self.layer_pattern) == self.num_layers, self.name
            return self.layer_pattern
        if self.moe is not None:
            return ("moe",) * self.num_layers
        return ("attn",) * self.num_layers

    def n_params(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        from repro.analysis.flops import param_count
        return param_count(self)

    def n_active_params(self) -> int:
        from repro.analysis.flops import param_count
        return param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"
    # number of gradient-accumulation microbatches for train shapes
    microbatches: int = 1

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k":    ShapeCfg("train_4k", 4096, 256, "train", microbatches=8),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeCfg("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ArchConfig, layers: int = 2, d_model: int = 64,
            vocab: int = 512) -> ArchConfig:
    """A smoke-test-sized config of the same family (pattern preserved)."""
    scale = d_model / cfg.d_model
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, min(n_heads, cfg.n_kv_heads))
    # preserve the pattern *shape*: keep one occurrence of each block type and
    # the first `layers` entries of the pattern cycle.
    pat = cfg.pattern
    types_seen = []
    small_pat = []
    for t in pat:
        small_pat.append(t)
        if t not in types_seen:
            types_seen.append(t)
        if len(small_pat) >= layers and set(types_seen) == set(pat):
            break
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                  expert_d_ff=4 * d_model, spare_slots=2)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, state_size=16, head_dim=16, chunk=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=len(small_pat),
        layer_pattern=tuple(small_pat),
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=4 * d_model,
        vocab=vocab,
        moe=moe,
        ssm=ssm,
        enc_layers=min(cfg.enc_layers, 2),
        enc_seq=min(cfg.enc_seq, 16),
        window=min(cfg.window, 8),
    )
