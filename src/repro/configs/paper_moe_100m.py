"""The paper-technique showcase config: a ~100M-param MoE LM used by the
end-to-end training example.  Reshape expert-skew mitigation, Amber control
plane, and Maestro region scheduling are all first-class on this config."""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="paper-moe-100m",
    family="moe",
    num_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=1024,
    vocab=32000,
    moe=MoECfg(num_experts=16, top_k=2, expert_d_ff=1024, spare_slots=2),
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="this work",
)
