"""zamba2-7b [hybrid]: 81L, d_model=3584, 32H (GQA kv=32), d_ff=14336,
vocab=32000, ssm_state=64.  Mamba2 backbone + SHARED attention+MLP block
applied every 6th layer (weights shared across all occurrences).  O(1) SSM
decode state + bounded attn reuse -> long_500k applicable.
[arXiv:2411.15242; unverified]"""
from repro.configs.base import ArchConfig, SSMCfg

# every 6th block is the shared attention block: 13 occurrences in 81 layers.
_PATTERN = tuple(
    "shared_attn" if (i % 6) == 5 else "mamba" for i in range(81)
)

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    layer_pattern=_PATTERN,
    ssm=SSMCfg(state_size=64, head_dim=64, expand=2, chunk=128),
    rope_theta=10_000.0,
    tie_embeddings=True,
    subquadratic=True,
    source="arXiv:2411.15242",
)
