"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeCfg, SHAPES, reduced

_MODULES = {
    "whisper-base": "repro.configs.whisper_base",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "yi-34b": "repro.configs.yi_34b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "paper-moe-100m": "repro.configs.paper_moe_100m",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "paper-moe-100m")


def get_arch(name: str) -> ArchConfig:
    import importlib
    if name.endswith("-smoke"):
        return reduced(get_arch(name[: -len("-smoke")]))
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_shape(name: str) -> ShapeCfg:
    return SHAPES[name]


def cell_applicable(arch: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Is this (arch x shape) dry-run cell runnable?  See DESIGN.md §4."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("pure full-attention arch: 512k dense KV cache per layer "
                       "is the non-sub-quadratic case (skip per assignment)")
    if shape.kind == "train" and arch.family == "audio":
        # whisper trains enc-dec on (audio frames -> text); supported.
        return True, ""
    return True, ""


def all_cells():
    """Yield (arch_name, shape_name, applicable, reason) for all 40 cells."""
    for a in ARCH_IDS:
        arch = get_arch(a)
        for s in SHAPES:
            ok, why = cell_applicable(arch, SHAPES[s])
            yield a, s, ok, why
