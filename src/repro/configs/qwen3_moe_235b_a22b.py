"""qwen3-moe-235b-a22b [moe]: 94L, d_model=4096, 64H (GQA kv=4), expert
d_ff=1536, vocab=151936, MoE 128 experts top-8.  head_dim=128 (q dim 8192 !=
d_model, as in the Qwen3 family).  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    moe=MoECfg(num_experts=128, top_k=8, expert_d_ff=1536, spare_slots=16),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-30B-A3B",
)
