"""qwen2-vl-7b [vlm]: 28L, d_model=3584, 28H (GQA kv=4), d_ff=18944,
vocab=152064.  M-RoPE (3-section rotary over t/h/w position ids), dynamic
resolution.  Transformer BACKBONE only; the vision patch-embedding frontend is
a stub — ``input_specs()`` provides precomputed patch embeddings and 3-D
position ids.  [arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    mrope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="arXiv:2409.12191",
)
