"""olmoe-1b-7b [moe]: 16L, d_model=2048, 16H (GQA kv=16), expert d_ff=1024,
vocab=50304, MoE 64 experts top-8.  The primary Reshape-integration target:
expert-routing skew is mitigated by the paper's technique (SBR/SBK expert
replication & placement).  [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    moe=MoECfg(num_experts=64, top_k=8, expert_d_ff=1024, spare_slots=16),
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2409.02060",
)
