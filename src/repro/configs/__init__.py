from repro.configs.base import ArchConfig, MoECfg, SSMCfg, ShapeCfg, SHAPES, reduced
from repro.configs.registry import get_arch, get_shape, ARCH_IDS, all_cells, cell_applicable

__all__ = [
    "ArchConfig", "MoECfg", "SSMCfg", "ShapeCfg", "SHAPES", "reduced",
    "get_arch", "get_shape", "ARCH_IDS", "all_cells", "cell_applicable",
]
