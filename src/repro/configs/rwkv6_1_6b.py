"""rwkv6-1.6b [ssm]: 24L, d_model=2048 (attention-free), channel-mix
d_ff=7168, vocab=65536.  Finch — data-dependent decay.  head_size=64 -> 32
time-mix heads.  O(1) decode state -> long_500k applicable.
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    n_heads=32,            # time-mix heads = d_model / head_size
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    layer_pattern=("rwkv",) * 24,
    ssm=SSMCfg(state_size=64, head_dim=64, chunk=128),
    tie_embeddings=False,
    subquadratic=True,
    source="arXiv:2404.05892",
)
