"""gemma3-1b [dense]: 26L, d_model=1152, 4H (GQA kv=1), d_ff=6912,
vocab=262144.  5:1 local:global attention (window 1024), 128k ctx (32k ctx for
1b), head_dim=256.  Sub-quadratic enough for long_500k: 22/26 layers keep a
bounded window-1024 cache; the 4 global layers hold a sequence-sharded cache.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

# 5 local : 1 global, repeating; 26 layers -> 4 full cycles + 2 local tail.
_PATTERN = (("local",) * 5 + ("attn",)) * 4 + ("local",) * 2

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    layer_pattern=_PATTERN,
    window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    subquadratic=True,   # 22/26 layers windowed; global layers seq-sharded
    source="hf:google/gemma-3-1b-pt",
)
