"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H (kv=8), d_ff=2048,
vocab=51865.  Encoder-decoder; conv frontend stubbed (input_specs() provides
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                 # decoder layers
    enc_layers=6,
    enc_seq=1500,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    layer_pattern=("dec",) * 6,
    rope_theta=0.0,               # whisper uses learned/sinusoidal positions
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
