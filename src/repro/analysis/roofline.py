"""Three-term roofline per (arch x shape x mesh).

    compute    = FLOPs / (chips * 197e12)
    memory     = bytes / (chips * 819e9)
    collective = collective_bytes / (chips * 50e9)

FLOPs/bytes come from the analytic model (``analysis.flops``), collective
bytes from both the analytic model and the HLO-text parse (trip-count
corrected).  The dominant term is the bottleneck the §Perf loop iterates on;
roofline fraction = compute_term / max(all terms) (how close the cell runs
to its compute roof if perfectly overlapped)."""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.analysis import flops as F
from repro.configs.base import ArchConfig, ShapeCfg
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    hlo_collective_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def usefulness(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roof achieved assuming perfect overlap:
        T_step = max(terms); fraction = useful-compute-time / T_step."""
        t = max(self.compute_s, self.memory_s, self.collective_s)
        useful = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return useful / max(t, 1e-30)

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.compute_s:.3e} | "
                f"{self.memory_s:.3e} | {self.collective_s:.3e} | "
                f"{self.dominant} | {self.model_flops:.3e} | "
                f"{self.usefulness:.2f} | {self.roofline_fraction:.2%} |")


def analyze(cfg: ArchConfig, shape: ShapeCfg, mesh_shape: Dict[str, int],
            remat: str = "none", fsdp: bool = True,
            hlo_text: Optional[str] = None, layout: str = "tp",
            kv_bytes: int = 2, seq_shard_decode: bool = False) -> Roofline:
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    m = mesh_shape.get("model", 1)
    fc = F.step_flops(cfg, shape, remat)
    bytes_dev = F.step_bytes_per_device(cfg, shape, chips, m, remat,
                                        kv_bytes, seq_shard_decode)
    coll_dev = F.collective_bytes_per_device(cfg, shape, mesh_shape, fsdp,
                                             layout)
    hlo_coll = 0.0
    if hlo_text is not None:
        from repro.analysis.hlo import total_collective_bytes
        hlo_coll = total_collective_bytes(hlo_text, cfg.num_layers)
    return Roofline(
        arch=cfg.name, shape=shape.name, chips=chips,
        compute_s=fc.hlo_flops / (chips * PEAK_FLOPS_BF16),
        memory_s=bytes_dev / HBM_BW,
        collective_s=coll_dev / ICI_BW,
        model_flops=fc.model_flops,
        hlo_flops=fc.hlo_flops,
        hlo_collective_bytes=hlo_coll,
    )


HEADER = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "bottleneck | MODEL_FLOPS | useful | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|---|")
