"""Analytic FLOPs / bytes / collective accounting per (arch x shape x mesh).

Why analytic: XLA's ``cost_analysis()`` counts ``scan`` (while-loop) bodies
ONCE (verified empirically — see DESIGN.md §3), so for scan-over-layers
programs it under-reports by ~num_layers.  We control every op in the model,
so exact per-block accounting is straightforward; ``cost_analysis()`` on the
unrolled 1–2-layer variants cross-checks these numbers (test_roofline).

Conventions: flops counted as 2*MACs; bf16 compute (2 bytes); fp32 master
params/optimizer.  MODEL_FLOPS follows the 6*N*D (dense) / 6*N_active*D (MoE)
convention; HLO_FLOPS additionally pays attention scores, capacity padding,
and remat recompute — the usefulness ratio MODEL/HLO quantifies that waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import ArchConfig, ShapeCfg

BF16 = 2
F32 = 4


# ------------------------------------------------------------------ params

def _block_params(cfg: ArchConfig, t: str) -> int:
    d, f = cfg.d_model, cfg.d_ff
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * (h + 2 * kh) * hd + h * hd * d
    if t in ("attn", "local", "shared_attn"):
        return attn + 3 * d * f + 2 * d
    if t == "moe":
        m = cfg.moe
        s = m.num_experts + m.spare_slots
        return attn + d * m.num_experts + s * 3 * d * m.expert_d_ff + 2 * d
    if t == "rwkv":
        lora = 64
        # wr wk wv wg wo wcr = 6 d^2; lora pair; wck+wcv; 6 mu + 3 ln + w0;
        # u bonus
        return (6 * d * d + 2 * d * lora + 2 * d * f + 10 * d + h * hd)
    if t == "mamba":
        ssm = cfg.ssm
        di = ssm.expand * d
        nh = di // ssm.head_dim
        n = ssm.state_size
        return (d * (2 * di + 2 * n + nh) + ssm.conv_kernel * (di + 2 * n)
                + di * d + di + 3 * nh + d)
    if t in ("enc", "dec"):
        cross = attn if t == "dec" else 0
        lns = 3 if t == "dec" else 2
        return attn + cross + 2 * d * f + lns * d
    raise KeyError(t)


def param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    total = cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.vocab
    total += cfg.d_model
    from repro.models.lm import type_counts
    for t, n in type_counts(cfg).items():
        cnt = 1 if t == "shared_attn" else n
        p = _block_params(cfg, t)
        if active_only and t == "moe":
            m = cfg.moe
            s = m.num_experts + m.spare_slots
            expert = s * 3 * cfg.d_model * m.expert_d_ff
            p = p - expert + m.top_k * 3 * cfg.d_model * m.expert_d_ff
        total += cnt * p
    if cfg.enc_layers:
        total += cfg.enc_layers * _block_params(cfg, "enc") + cfg.d_model
    return int(total)


# ------------------------------------------------------------------- flops

def _attn_score_flops(cfg: ArchConfig, s_ctx: float) -> float:
    """Per query token: QK^T + PV over s_ctx keys, all heads."""
    return 2 * 2 * s_ctx * cfg.n_heads * cfg.hd


def _block_fwd_flops_per_token(cfg: ArchConfig, t: str, s_ctx: float,
                               padded_moe: bool = True) -> float:
    d, f = cfg.d_model, cfg.d_ff
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn_proj = 2 * d * (h + 2 * kh) * hd + 2 * h * hd * d
    if t in ("attn", "shared_attn", "enc"):
        mlp = 2 * 3 * d * f if t not in ("enc", "dec") else 2 * 2 * d * f
        return attn_proj + _attn_score_flops(cfg, s_ctx) + mlp
    if t == "local":
        return attn_proj + _attn_score_flops(cfg, min(s_ctx, cfg.window)) + \
            2 * 3 * d * f
    if t == "dec":
        return (2 * attn_proj + _attn_score_flops(cfg, s_ctx)
                + _attn_score_flops(cfg, cfg.enc_seq) + 2 * 2 * d * f)
    if t == "moe":
        m = cfg.moe
        router = 2 * d * m.num_experts
        eff_k = m.top_k * (m.capacity_factor if padded_moe else 1.0)
        expert = eff_k * 2 * 3 * d * m.expert_d_ff
        return attn_proj + _attn_score_flops(cfg, s_ctx) + router + expert
    if t == "rwkv":
        n = cfg.hd
        tm = 2 * 4 * d * d + 2 * 2 * d * 64          # r,k,v,g + decay lora
        wkv = 2 * 3 * d * n                          # state upd + r.S per head
        out = 2 * d * d
        cm = 2 * (2 * d * f + d * d)
        return tm + wkv + out + cm
    if t == "mamba":
        ssm = cfg.ssm
        di = ssm.expand * d
        nh = di // ssm.head_dim
        n = ssm.state_size
        q = ssm.chunk
        proj = 2 * d * (2 * di + 2 * n + nh) + 2 * di * d
        conv = 2 * ssm.conv_kernel * (di + 2 * n)
        # chunked SSD per token: C@B^T [Q,N]->[Q,Q] amortized + att@x + state
        ssd = 2 * q * n + 2 * q * ssm.head_dim * nh / max(nh, 1) * nh + \
            4 * di * n
        return proj + conv + ssd
    raise KeyError(t)


def fwd_flops_per_token(cfg: ArchConfig, s_ctx: float,
                        padded_moe: bool = True) -> float:
    from repro.models.lm import type_counts
    total = 2 * cfg.d_model * cfg.vocab              # lm head
    for t, n in type_counts(cfg).items():
        total += n * _block_fwd_flops_per_token(cfg, t, s_ctx, padded_moe)
    return total


@dataclasses.dataclass
class FlopCount:
    model_flops: float      # 6*N*D convention (active params)
    hlo_flops: float        # what the compiled program actually executes


def step_flops(cfg: ArchConfig, shape: ShapeCfg,
               remat: str = "none") -> FlopCount:
    toks = shape.tokens
    if shape.kind == "train":
        n_active = param_count(cfg, active_only=True)
        emb = cfg.vocab * cfg.d_model * (2 if not cfg.tie_embeddings else 1)
        model = 6.0 * (n_active - emb + cfg.d_model * cfg.vocab) * toks
        fwd = fwd_flops_per_token(cfg, shape.seq_len / 2) * toks
        mult = 3.0 + (1.0 if remat == "full" else
                      0.3 if remat == "dots" else 0.0)
        if cfg.enc_layers:
            enc = _block_fwd_flops_per_token(cfg, "enc", cfg.enc_seq) * \
                cfg.enc_layers * shape.global_batch * cfg.enc_seq
            fwd += enc * 1.0
        return FlopCount(model, fwd * mult)
    # decode (one token, cache of seq_len) or prefill
    if shape.kind == "prefill":
        n_active = param_count(cfg, active_only=True)
        model = 2.0 * n_active * toks
        return FlopCount(model, fwd_flops_per_token(cfg, shape.seq_len / 2)
                         * toks)
    n_active = param_count(cfg, active_only=True)
    b = shape.global_batch
    model = 2.0 * n_active * b
    return FlopCount(model, fwd_flops_per_token(cfg, shape.seq_len) * b)


# ------------------------------------------------------------------- bytes

def step_bytes_per_device(cfg: ArchConfig, shape: ShapeCfg, chips: int,
                          model_ways: int, remat: str = "none",
                          kv_bytes: int = BF16,
                          seq_shard_decode: bool = False) -> float:
    """HBM traffic per device per step (weights + activations + caches)."""
    n = param_count(cfg)
    if shape.kind == "train":
        # fwd+bwd read weights twice, write grads once; adam reads/writes
        w = n / chips * (2 * BF16 + 1 * F32 + 4 * F32)
        act_factor = {"none": 14, "dots": 8, "full": 4}[remat]
        from repro.models.lm import type_counts
        acts = shape.tokens / chips * cfg.d_model * BF16 * act_factor * \
            cfg.num_layers
        return w + acts
    if shape.kind == "prefill":
        w = n * BF16 / model_ways       # weights read once, model-sharded
        acts = shape.tokens / chips * cfg.d_model * BF16 * 8 * cfg.num_layers
        return w + acts
    # decode: weights + KV cache stream through HBM once per token.
    # Weights are model-sharded; every device in a data row reads its own
    # copy of the model shard (batch within the row shares the read).
    # decode2d (seq_shard_decode): weights 2-D sharded over ALL chips
    # (weight-stationary), cache sequence-sharded -> both ~1/chips.
    w = n * BF16 / (chips if seq_shard_decode else model_ways)
    cache = _cache_bytes(cfg, shape, kv_bytes) / chips
    act = shape.global_batch * cfg.d_model * BF16 * 12 * cfg.num_layers / chips
    return w + cache + act


def _cache_bytes(cfg: ArchConfig, shape: ShapeCfg,
                 kv_bytes: int = BF16) -> float:
    from repro.models.lm import type_counts
    b, s = shape.global_batch, shape.seq_len
    total = 0.0
    for t, cnt in type_counts(cfg).items():
        if t in ("attn", "moe", "shared_attn", "dec"):
            total += cnt * b * s * 2 * cfg.n_kv_heads * cfg.hd * kv_bytes
        elif t == "local":
            total += cnt * b * min(s, cfg.window) * 2 * cfg.n_kv_heads * \
                cfg.hd * kv_bytes
        elif t == "rwkv":
            total += cnt * b * (cfg.n_heads * cfg.hd * cfg.hd * F32
                                + 2 * cfg.d_model * BF16)
        elif t == "mamba":
            ssm = cfg.ssm
            di = ssm.expand * cfg.d_model
            total += cnt * b * ((di // ssm.head_dim) * ssm.head_dim *
                                ssm.state_size * F32 +
                                (ssm.conv_kernel - 1) * (di + 2 * ssm.state_size) * BF16)
    return total


# -------------------------------------------------------------- collectives

def collective_bytes_per_device(cfg: ArchConfig, shape: ShapeCfg,
                                mesh_shape: Dict[str, int],
                                fsdp: bool = True,
                                layout: str = "tp") -> float:
    """Per-device bytes over ICI per step (ring-collective convention:
    all-reduce of S bytes costs 2*S*(k-1)/k per device; all-gather /
    reduce-scatter cost S*(k-1)/k).

    layout "tp": batch over data, weights Megatron-TP over model (2 act
    all-reduces/layer + MoE psum-combine).  layout "dp": batch over
    data x model (attention/SSM fully local), MoE via all-to-all; weights
    FSDP over both axes (all-gathered per step)."""
    m = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = m * dp
    n = param_count(cfg)
    d = cfg.d_model
    total = 0.0
    if shape.kind == "train" and layout == "dp":
        toks_dev = shape.tokens / chips
        if cfg.moe is not None and m > 1:
            # dispatch + combine a2a of per-device routed tokens
            a2a = toks_dev * cfg.moe.top_k * d * BF16
            total += 2 * cfg.num_layers * a2a * (m - 1) / m
        # experts stay EP-sharded over model (only FSDP'd over data);
        # the DENSE part must be fully gathered per device per step.
        n_exp = 0
        if cfg.moe is not None:
            mo = cfg.moe
            s_slots = mo.num_experts + mo.spare_slots
            n_exp = cfg.num_layers * s_slots * 3 * d * mo.expert_d_ff
        n_dense = n - n_exp
        total += (2 * n_dense * BF16 + n_dense * F32) * (chips - 1) / chips
        if n_exp:
            total += (2 * n_exp * BF16 / m + n_exp * F32 / m) * (dp - 1) / dp
        return total
    if shape.kind == "train":
        toks_dev = shape.tokens / dp            # batch sharded over dp
        # TP: 2 activation all-reduces per layer of [toks_dev, d] bf16
        if m > 1:
            ar = toks_dev * d * BF16
            total += cfg.num_layers * 2 * 2 * ar * (m - 1) / m
        if fsdp and dp > 1:
            shard = n * BF16 / m                # per model-column params
            # all-gather fwd + bwd, reduce-scatter grads (fp32)
            total += (2 * shard + n * F32 / m) * (dp - 1) / dp
        elif dp > 1:
            total += 2 * n * F32 / m * (dp - 1) / dp   # plain DP all-reduce
        if cfg.moe is not None and m > 1:
            # dispatch + combine all-to-alls of k-way routed tokens
            a2a = toks_dev * cfg.moe.top_k * d * BF16
            total += 2 * a2a * (m - 1) / m
    else:
        b_eff = shape.global_batch if shape.kind == "decode" else shape.tokens
        per_dev = max(1.0, b_eff / dp)
        if m > 1:
            ar = per_dev * d * BF16
            total += cfg.num_layers * 2 * 2 * ar * (m - 1) / m
            total += per_dev * cfg.vocab * F32 / m * (m - 1) / m  # logits
        if cfg.moe is not None and m > 1:
            a2a = per_dev * cfg.moe.top_k * d * BF16
            total += 2 * a2a * (m - 1) / m
        if shape.global_batch < dp and shape.kind == "decode":
            # sequence-sharded cache: partial-softmax combine per layer
            total += cfg.num_layers * 2 * cfg.n_heads * 3 * F32 * (dp - 1) / dp
    return total
