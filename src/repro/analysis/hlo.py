"""HLO-text analysis: collective-op byte accounting.

``lowered.as_text()`` of an SPMD program contains every collective op with
its operand shapes.  Collectives inside ``while`` bodies (scan-over-layers)
appear ONCE in the text, so we report both the raw text sum and a
trip-count-corrected sum: computations reachable from a while body are
multiplied by the scan trip count, which the caller knows from the config
(all our scans are over layer stacks)."""
from __future__ import annotations

import re
from collections import Counter, defaultdict
from typing import Dict, Optional, Tuple

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_BODY_RE = re.compile(r"body=%([\w.\-]+)")


def _body_depths(hlo_text: str) -> Dict[str, int]:
    """Nesting depth of every while-body computation (1 = outermost loop).
    Built from the body=%X references: a body referenced from inside another
    body is one level deeper."""
    # computation -> list of bodies it invokes
    children: Dict[str, list] = defaultdict(list)
    current = ""
    for line in hlo_text.splitlines():
        if line.startswith("%") and "(" in line:
            current = line.strip().split(" ")[0].lstrip("%")
        elif line.startswith("ENTRY"):
            current = "__entry__"
        for b in _BODY_RE.findall(line):
            children[current].append(b)
    depths: Dict[str, int] = {}

    def visit(comp: str, depth: int):
        for b in children.get(comp, ()):
            if depths.get(b, 0) < depth:
                depths[b] = depth
                visit(b, depth + 1)
    visit("__entry__", 1)
    # bodies referenced from non-entry, non-body computations (e.g. called
    # fusions) — treat their top-level whiles as depth 1
    for comp in list(children):
        if comp not in depths and comp != "__entry__":
            if comp not in depths:
                for b in children[comp]:
                    if b not in depths:
                        depths[b] = depths.get(comp, 0) + 1
                        visit(b, depths[b] + 1)
    return depths


def collective_bytes(hlo_text: str,
                     while_multiplier=1.0) -> Dict[str, float]:
    """Sum operand bytes per collective kind, with nesting-aware loop
    multipliers.  ``while_multiplier`` may be a scalar (applied to every
    loop level, legacy) or a list of per-depth trip counts (e.g. [mb, L]
    for a microbatch scan containing a layer scan): an op at depth d gets
    the product of the first d trip counts (deeper levels reuse the last).
    """
    if isinstance(while_multiplier, (int, float)):
        trips = [float(while_multiplier)]
    else:
        trips = [float(x) for x in while_multiplier] or [1.0]
    depths = _body_depths(hlo_text)

    def mult_for(comp: str) -> float:
        # multiply only the loop levels whose trip counts the caller knows
        # (deeper unknown loops — e.g. attention kv-chunk scans — count once)
        d = depths.get(comp.lstrip("%"), 0)
        m = 1.0
        for i in range(min(d, len(trips))):
            m *= trips[i]
        return m

    bodies = set(depths)
    out: Dict[str, float] = defaultdict(float)
    counts: Counter = Counter()
    current_comp = ""
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers start at column 0; instruction lines are
        # indented (their shape layouts also contain '{', so indentation is
        # the only reliable discriminator)
        if line.startswith("%") and "(" in stripped:
            current_comp = stripped.split(" ")[0]
        elif line.startswith("ENTRY") or (stripped.startswith("ENTRY")
                                          and not line.startswith(" ")):
            current_comp = stripped.split(" ")[0]
        current_in_body = current_comp.lstrip("%") in bodies
        for kind in COLLECTIVES:
            token = f" {kind}(" if f" {kind}(" in line else (
                f"{kind}(" if f"= {kind}" in line or f"{kind}-start(" in line
                else None)
            if (f" {kind}(" in line or f"{kind}-start(" in line or
                    re.search(rf"= \S*\s*{kind}", line)):
                # operand bytes ~ the op's RESULT shape, which sits between
                # '=' and the op name:  %x = f32[a,b]{...} all-reduce(...)
                rhs = line.split("=", 1)[1] if "=" in line else line
                head = rhs.split(kind, 1)[0]
                b = _shape_bytes(head)
                if b == 0:
                    b = _shape_bytes(rhs)
                mult = mult_for(current_comp) if (
                    current_in_body or _in_loop(current_comp, line)) else 1.0
                out[kind] += b * mult
                counts[kind] += 1
                break
    out["_ops"] = dict(counts)  # type: ignore
    return dict(out)


def _in_loop(comp_name: str, line: str) -> bool:
    lowered = comp_name.lower()
    return any(k in lowered for k in ("while", "body", "scan", "loop"))


def total_collective_bytes(hlo_text: str, while_multiplier=1.0) -> float:
    d = collective_bytes(hlo_text, while_multiplier)
    return float(sum(v for k, v in d.items() if not k.startswith("_")))
