import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.

"""Multi-pod dry-run: .lower().compile() every (arch x shape) cell on the
single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, using ShapeDtypeStruct
stand-ins (zero allocation).  Records memory_analysis / cost_analysis /
HLO collective stats per cell for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--mesh single|multi|both]
      [--arch <id>[,<id>..]] [--shape <name>[,..]] [--remat none|dots|full]
      [--out results.json] [--hlo-dir dir]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as RL
from repro.analysis.hlo import collective_bytes
from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_arch, get_shape
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.runtime import sharding as SH
from repro.runtime.serve import abstract_serve_inputs, build_serve_step
from repro.runtime.train import TrainHyper, abstract_state, build_train_step, loss_fn


def input_specs(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.enc_layers:
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model),
                                             jnp.bfloat16)
    if cfg.mrope:
        out["positions3"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
    return out


def plan_abstract(cfg):
    nl = lm.n_moe_layers(cfg)
    if nl == 0:
        return (jax.ShapeDtypeStruct((1, 1, 1), jnp.int32),
                jax.ShapeDtypeStruct((1, 1, 1), jnp.float32))
    e, r = cfg.moe.num_experts, cfg.moe.max_replicas
    return (jax.ShapeDtypeStruct((nl, e, r), jnp.int32),
            jax.ShapeDtypeStruct((nl, e, r), jnp.float32))


def lower_cell(arch_name, shape_name, mesh, remat="none", hlo_dir=None,
               layout="tp", kv_dtype=None, force_seq_shard=False,
               microbatches=None):
    cfg = get_arch(arch_name)
    if remat != "none":
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = get_shape(shape_name)
    if microbatches:
        shape = dataclasses.replace(shape, microbatches=microbatches)
    pspec = SH.param_specs(cfg, mesh)
    t0 = time.perf_counter()
    nl_moe = lm.n_moe_layers(cfg)
    plan_specs = (P(), P())

    da_ = SH.data_axes(mesh)
    act_spec = SH.act_spec_for(cfg, shape, mesh, layout)
    if shape.kind == "train":
        hyper = TrainHyper(remat=remat)
        step = build_train_step(cfg, shape, hyper, mesh=mesh,
                                act_spec=act_spec, layout=layout)
        state = abstract_state(cfg)
        state_specs = {"params": pspec,
                       "opt": SH.opt_state_specs(pspec),
                       "step": P()}
        # opt moments share the param specs leaf-for-leaf
        state_specs["opt"] = type(state["opt"])(pspec, pspec, P())
        bspecs = SH.batch_specs(cfg, shape, mesh, layout)
        batch = input_specs(cfg, shape)
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(state_specs, bspecs) + plan_specs,
                out_shardings=(state_specs, None),
            ).lower(state, batch, *plan_abstract(cfg))
    elif shape.kind == "prefill":
        hyper = TrainHyper(remat="none")

        def prefill(params, batch, ps, pc):
            from repro.models import moe as moe_lib
            plan = moe_lib.RoutingPlan(ps, pc) if nl_moe else None
            logits, _ = lm.forward(params, batch, cfg, plan=plan, mesh=mesh,
                                   act_spec=act_spec)
            return logits

        bspecs = SH.batch_specs(cfg, shape, mesh)
        batch = input_specs(cfg, shape)
        da = SH.data_axes(mesh)
        vshard = "model" if cfg.vocab % SH.axis_size(mesh, "model") == 0 \
            else None
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                prefill,
                in_shardings=(pspec, bspecs) + plan_specs,
                out_shardings=NamedSharding(mesh, P(da, None, vshard)),
            ).lower(lm.abstract(cfg, jnp.bfloat16), batch,
                    *plan_abstract(cfg))
    else:  # decode
        import jax.numpy as _jnp
        da = SH.data_axes(mesh)
        dp = SH.axis_size(mesh, da)
        toks_sharded = shape.global_batch >= dp and not force_seq_shard
        step = build_serve_step(cfg, mesh=mesh, tokens_sharded=toks_sharded)
        kdt = {None: None, "bf16": None,
               "f8": _jnp.float8_e4m3fn}[kv_dtype]
        cache_abs, token = abstract_serve_inputs(cfg, shape, kdt)
        cspecs = SH.cache_specs(cfg, mesh, shape, cache_abs,
                                force_seq_shard=force_seq_shard)
        tok_spec = P(da, None) if toks_sharded else P(None, None)
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(pspec, cspecs, tok_spec) + plan_specs,
                out_shardings=(None, cspecs),
            ).lower(lm.abstract(cfg, jnp.bfloat16), cache_abs, token,
                    *plan_abstract(cfg))
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_bytes(txt, cfg.num_layers)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(
                hlo_dir, f"{arch_name}_{shape_name}_{len(mesh.devices.flat)}"
                f".txt"), "w") as f:
            f.write(txt)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    rl = RL.analyze(cfg, shape, mesh_shape, remat=remat, hlo_text=None,
                    layout=layout, kv_bytes=1 if kv_dtype == "f8" else 2,
                    seq_shard_decode=force_seq_shard)
    rl.hlo_collective_bytes = float(
        sum(v for k, v in coll.items() if not k.startswith("_")))
    return {
        "arch": arch_name, "shape": shape_name,
        "mesh": "x".join(str(d) for d in mesh.devices.shape),
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "total_per_device_gb": round(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                 + ma.output_size_in_bytes) / 2 ** 30, 3),
        },
        "cost_analysis": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collectives": {k: v for k, v in coll.items()},
        "roofline": {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "dominant": rl.dominant,
            "model_flops": rl.model_flops, "hlo_flops": rl.hlo_flops,
            "usefulness": rl.usefulness,
            "roofline_fraction": rl.roofline_fraction,
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--layout", default="tp", choices=["tp", "dp"])
    ap.add_argument("--kv-dtype", default=None, choices=[None, "bf16", "f8"])
    ap.add_argument("--force-seq-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for a in archs:
            cfg = get_arch(a)
            for s in shapes:
                ok, why = cell_applicable(cfg, SHAPES[s])
                tag = f"{a} x {s} x {'2x16x16' if multi else '16x16'}"
                if not ok:
                    print(f"SKIP {tag}: {why}", flush=True)
                    results.append({"arch": a, "shape": s,
                                    "mesh": "2x16x16" if multi else "16x16",
                                    "ok": None, "skip_reason": why})
                    continue
                try:
                    r = lower_cell(a, s, mesh, args.remat, args.hlo_dir,
                                   layout=args.layout, kv_dtype=args.kv_dtype,
                                   force_seq_shard=args.force_seq_shard,
                                   microbatches=args.microbatches)
                    rr = r["roofline"]
                    print(f"PASS {tag}: compile={r['compile_s']}s "
                          f"mem/dev={r['memory']['total_per_device_gb']}GB "
                          f"dominant={rr['dominant']} "
                          f"roofline={rr['roofline_fraction']:.1%}",
                          flush=True)
                    results.append(r)
                except Exception as e:
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
                    results.append({"arch": a, "shape": s,
                                    "mesh": "2x16x16" if multi else "16x16",
                                    "ok": False, "error": str(e)[:500]})
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    n_pass = sum(1 for r in results if r.get("ok"))
    n_fail = sum(1 for r in results if r.get("ok") is False)
    n_skip = sum(1 for r in results if r.get("ok") is None)
    print(f"\n== dry-run: {n_pass} pass, {n_fail} fail, {n_skip} skip "
          f"-> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
