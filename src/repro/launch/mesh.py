"""Production mesh construction.  A FUNCTION, not a module-level constant —
importing this module never touches jax device state."""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh with the production axis names (smoke tests)."""
    import jax
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline targets; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
