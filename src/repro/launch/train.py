"""Training launcher.

CPU-scale end-to-end run (the container):
  PYTHONPATH=src python -m repro.launch.train --arch paper-moe-100m-smoke \\
      --steps 100 --reshape --ckpt-dir /tmp/ck

Cluster-scale (TPU pod; same code path, production mesh + jit step):
  python -m repro.launch.train --arch olmoe-1b-7b --shape train_4k \\
      --mesh single --steps 10000
"""
from __future__ import annotations

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-moe-100m-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reshape", action="store_true",
                    help="enable Reshape expert-skew mitigation")
    ap.add_argument("--class-alpha", type=float, default=1.5,
                    help="token-class Zipf skew (drives routing skew)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ep-ranks", type=int, default=2)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.core.reshape_moe import MoEReshaper
    from repro.core.skew import SkewParams
    from repro.data.synthetic import TokenStream
    from repro.models import lm
    from repro.optim.adamw import AdamWCfg
    from repro.runtime.loop import LoopConfig, TrainLoop
    from repro.runtime.train import TrainHyper

    cfg = get_arch(args.arch)
    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq_len,
                         global_batch=args.global_batch, seed=0,
                         class_alpha=args.class_alpha)
    hyper = TrainHyper(opt=AdamWCfg(lr=args.lr, warmup_steps=20,
                                    total_steps=max(args.steps, 100)))
    lc = LoopConfig(microbatches=args.microbatches,
                    ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir or "/tmp/repro_train_ckpt")
    reshaper = None
    if args.reshape and lm.n_moe_layers(cfg):
        reshaper = MoEReshaper(cfg, lm.n_moe_layers(cfg),
                               ep_ranks=args.ep_ranks,
                               params=SkewParams(eta=0.0, tau=0.2))
    if args.resume:
        loop = TrainLoop.recover(cfg, stream, hyper, lc, reshaper=reshaper)
        print(f"recovered at step {int(loop.state['step'])}")
    else:
        loop = TrainLoop(cfg, stream, hyper, lc, reshaper=reshaper)
    t0 = time.perf_counter()
    hist = loop.run(args.steps)
    dt = time.perf_counter() - t0
    for h in hist[:: max(1, len(hist) // 20)]:
        extra = ""
        if "dropped" in h:
            extra = f" dropped={int(h['dropped'].sum())}"
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.2f}{extra}")
    print(f"\n{len(hist)} steps in {dt:.1f}s "
          f"({len(hist) / max(dt, 1e-9):.2f} steps/s)")
    if reshaper is not None:
        print(f"reshape iterations: {reshaper.iterations}; "
              f"events: {len(reshaper.events)}")


if __name__ == "__main__":
    main()
