"""Synthetic data substrate: token streams with controllable key skew.

Provides (a) LM token batches (checkpointable iterator state), (b) skewed
key streams for the Tier-A simulator benchmarks (Zipf / tweets-like /
shifting distributions, paper §3.7.1 Fig 3.15), and (c) a class-structured
token stream where the token's leading id encodes a "class" (location-like)
so result-representativeness (CA:AZ curves) is measurable on the MoE runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np


def zipf_weights(n: int, alpha: float = 1.2) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** alpha
    return w / w.sum()


def tweets_like_rates(n_keys: int = 50, hot: float = 26.0,
                      mid: float = 6.5, low: float = 3.8) -> Dict[int, float]:
    """Tweet-location-like distribution (CA=26M, IL=6.5M, AZ=3.8M scaled)."""
    rates = {k: 1.0 for k in range(n_keys)}
    rates[6] = hot          # "CA"
    rates[17] = mid         # "IL"
    rates[4] = low          # "AZ"
    if n_keys > 48:
        rates[48] = hot * 0.6   # "TX"
    return rates


def shifting_rates(change_tick: int, before: Dict[int, float],
                   after: Dict[int, float]) -> Callable[[int], Dict[int, float]]:
    return lambda t: before if t < change_tick else after


@dataclasses.dataclass
class TokenStream:
    """Deterministic, checkpointable LM batch source.

    ``class_skew``: if set, tokens are drawn per-sequence from a "class"
    whose vocab slice is Zipf-hot — creating the routing skew Reshape
    mitigates, with measurable per-class throughput.
    """
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0
    n_classes: int = 8
    class_alpha: float = 0.0          # 0 = uniform tokens, >0 = skewed
    shift_at: Optional[int] = None    # distribution shift step (Fig 3.24)

    def class_probs(self) -> np.ndarray:
        if self.class_alpha <= 0:
            return np.full(self.n_classes, 1.0 / self.n_classes)
        p = zipf_weights(self.n_classes, self.class_alpha)
        if self.shift_at is not None and self.step >= self.shift_at:
            p = np.roll(p, self.n_classes // 2)
        return p

    def next(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 1_000_003 + self.step)
        b, s, v = self.global_batch, self.seq_len, self.vocab
        probs = self.class_probs()
        cls = rng.choice(self.n_classes, size=(b,), p=probs)
        lo = (cls * (v // self.n_classes))[:, None]
        tokens = lo + rng.integers(1, v // self.n_classes,
                                   size=(b, s))
        self.step += 1
        return {"tokens": tokens.astype(np.int32),
                "classes": cls.astype(np.int32)}

    # checkpointable iterator state (recovery replays from here)
    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict) -> "TokenStream":
        self.seed, self.step = state["seed"], state["step"]
        return self
