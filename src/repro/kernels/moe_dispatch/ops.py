"""Dispatch wrappers + custom VJPs for the fused MoE dispatch/combine family.

Impl resolution mirrors ``moe_gating``: ``pallas`` on TPU, the vectorized
jnp implementation of the same fused algorithm (``ref.py``) elsewhere —
Pallas interpret mode stays available (``impl="interpret"``) for validating
the kernel itself on CPU, but is a debugging mode, not a fast path.

Gradients: the routing decisions (slot, rank, keep, counts) are integers
and carry no gradient; the differentiable dataflow is the weighted scatter
(dispatch) and weighted gather (combine).  The two are transposes of each
other, so each one's VJP is the other kernel re-applied:

* ``d dispatch / d v``  = a combine of the buffer cotangent at the same
  (slot, rank, keep) — the "combine re-gather".
* ``d combine / d buf`` = a dispatch of the output cotangent; the rank is
  recomputed from the identical (slot, valid, cap) inputs, so the scatter
  lands in exactly the forward buckets.
* ``d / d w`` (per-assignment weight) is a row-wise dot of the cotangent
  with the gathered counterpart rows.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_dispatch.moe_dispatch import (combine_pallas,
                                                     dispatch_pallas)
from repro.kernels.moe_dispatch.ref import combine_ref, dispatch_ref


def block_rows(t: int, cap: int = 256) -> int:
    """Largest divisor of ``t`` that is <= cap (the kernels need
    t % bt == 0; gcd with a power of two collapses to 1-row blocks for
    odd t)."""
    for d in range(min(cap, t), 0, -1):
        if t % d == 0:
            return d
    return 1


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return impl


def _dispatch_raw(v, w, slot, valid, n_slots, cap, impl, bt):
    impl = _resolve(impl)
    if impl == "jnp":
        return dispatch_ref(v, w, slot, valid, n_slots, cap)
    return dispatch_pallas(v, w, slot, valid, n_slots, cap, bt=bt,
                           interpret=(impl == "interpret"))


def _combine_raw(buf, w, slot, rank, keep, impl, bt):
    impl = _resolve(impl)
    if impl == "jnp":
        return combine_ref(buf, w, slot, rank, keep)
    return combine_pallas(buf, w, slot, rank, keep, bt=bt,
                          interpret=(impl == "interpret"))


def _f0(a):
    """float0 cotangent for an integer primal."""
    return np.zeros(a.shape, jax.dtypes.float0)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _dispatch_f(v, w, slot, valid, n_slots, cap, impl, bt):
    """Fused rank + capacity + bucketed scatter.  v [T,D]; w/slot/valid
    [T,k] -> (buf [S,C,D], rank [T,k], keep [T,k], routed [S], kept [S]).

    The integer routing outputs are returned as f32: a custom_vjp's int
    outputs carry instantiated float0 tangents that break downstream JVP
    rules inside a differentiated ``lax.scan`` (the layer stack), while f32
    outputs get ordinary zero tangents.  ``dispatch`` casts them back."""
    buf, rank, keep, routed, kept = _dispatch_raw(v, w, slot, valid,
                                                  n_slots, cap, impl, bt)
    f = jnp.float32
    return (buf, rank.astype(f), keep.astype(f), routed.astype(f),
            kept.astype(f))


def _dispatch_fwd(v, w, slot, valid, n_slots, cap, impl, bt):
    buf, rank, keep, routed, kept = _dispatch_raw(v, w, slot, valid,
                                                  n_slots, cap, impl, bt)
    f = jnp.float32
    out = (buf, rank.astype(f), keep.astype(f), routed.astype(f),
           kept.astype(f))
    return out, (v, w, slot, valid, rank, keep)


def _dispatch_bwd(n_slots, cap, impl, bt, res, g):
    v, w, slot, valid, rank, keep = res
    g_buf = g[0]                    # integer outputs carry no cotangent
    dv = _combine_raw(g_buf, w, slot, rank, keep, impl, bt)
    t, k = slot.shape
    kb = keep != 0
    dest = jnp.where(kb, slot * cap + rank, 0).reshape(t * k)
    rows = g_buf.reshape(n_slots * cap, -1)[dest].reshape(t, k, -1)
    dw = (rows.astype(jnp.float32) *
          v[:, None, :].astype(jnp.float32)).sum(-1) * kb
    return dv.astype(v.dtype), dw.astype(w.dtype), _f0(slot), _f0(valid)


_dispatch_f.defvjp(_dispatch_fwd, _dispatch_bwd)


def dispatch(v, w, slot, valid, n_slots, cap, impl, bt):
    """Public fused dispatch; routing outputs as int32 (rank/keep [T,k],
    routed/kept [S]).  Counts round-trip through f32 (see ``_dispatch_f``),
    exact for T*k < 2**24."""
    assert v.shape[0] * slot.shape[1] < 2 ** 24
    buf, rank, keep, routed, kept = _dispatch_f(v, w, slot, valid, n_slots,
                                                cap, impl, bt)
    i = jnp.int32
    return (buf, rank.astype(i), keep.astype(i), routed.astype(i),
            kept.astype(i))


@partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def combine(buf, w, slot, rank, keep, valid, impl, bt):
    """Weighted gather back to token rows.  ``valid`` is only consumed by
    the VJP (it lets the backward scatter recompute the forward ranks)."""
    return _combine_raw(buf, w, slot, rank, keep, impl, bt)


def _combine_fwd(buf, w, slot, rank, keep, valid, impl, bt):
    y = _combine_raw(buf, w, slot, rank, keep, impl, bt)
    return y, (buf, w, slot, rank, keep, valid)


def _combine_bwd(impl, bt, res, g_y):
    buf, w, slot, rank, keep, valid = res
    s, cap, d = buf.shape
    # same (slot, valid, cap) => the dispatch recomputes the identical
    # rank/keep, so the cotangent scatter fills exactly the forward buckets
    d_buf = _dispatch_raw(g_y, w, slot, valid, s, cap, impl, bt)[0]
    t, k = slot.shape
    kb = keep != 0
    dest = jnp.where(kb, slot * cap + rank, 0).reshape(t * k)
    rows = buf.reshape(s * cap, d)[dest].reshape(t, k, d)
    dw = (rows.astype(jnp.float32) *
          g_y[:, None, :].astype(jnp.float32)).sum(-1) * kb
    return (d_buf.astype(buf.dtype), dw.astype(w.dtype), _f0(slot),
            _f0(rank), _f0(keep), _f0(valid))


combine.defvjp(_combine_fwd, _combine_bwd)


def dispatch_combine(x, slot, weight, expert_fn, n_slots: int, cap: int,
                     valid=None, impl: str = "auto", bt: int = 0):
    """Drop-in for ``models.moe.dispatch_combine`` on the fused kernels.

    Returns (y [T,D], metrics) with bit-identical token-drop decisions and
    Reshape load metrics (slot_counts = routed phi, kept_counts, dropped)
    vs the XLA argsort/searchsorted/scatter path.
    """
    t, _ = x.shape
    k = slot.shape[1]
    valid_i = (jnp.ones((t, k), jnp.int32) if valid is None
               else valid.astype(jnp.int32))
    bt = bt or block_rows(t)
    ones = jnp.ones((t, k), jnp.float32)
    buf, rank, keep, routed, kept = dispatch(x, ones, slot, valid_i,
                                             n_slots, cap, impl, bt)
    out_buf = expert_fn(buf)
    y = combine(out_buf, weight.astype(jnp.float32), slot, rank, keep,
                valid_i, impl, bt)
    dropped = valid_i.sum() - keep.sum()
    return y.astype(x.dtype), {"slot_counts": routed, "kept_counts": kept,
                               "dropped": dropped}
