"""Pallas TPU kernels: fused MoE dispatch (rank + capacity + bucketed
scatter) and combine (weighted gather).

The XLA baseline (``models.moe.dispatch_combine``) runs the hot rank/bucket
pipeline as four separate launches — ``argsort`` -> ``searchsorted`` ->
masked scatter-add into the ``[slots, cap, D]`` buffer -> gather/combine —
each round-tripping the ``[T*k]`` assignment arrays through HBM.  Here the
whole dispatch side is ONE kernel walking token blocks sequentially:

* **rank**: a VMEM-resident running histogram of routed tokens per slot is
  carried across grid steps (same trick as ``moe_gating``'s count output);
  within a block the rank is the histogram base plus an exclusive cumsum of
  the slot one-hot.  For a *stable* sort this equals the baseline's
  sorted-position-within-segment, so drop decisions are bit-identical.
* **capacity mask**: ``keep = valid & (rank < cap)`` on the fly.
* **bucketed scatter**: TPU has no fast vector scatter, so the scatter is a
  one-hot matmul — the block's ``[bt, S*C]`` destination multi-hot hits the
  MXU against the ``[bt, D]`` activations and accumulates into the VMEM
  buffer block.  Each kept assignment owns a unique ``(slot, rank)`` bucket,
  so the "sum" touches exactly one activation row per bucket (bit-exact).
* **load metrics**: routed/kept per-slot counts (the Reshape phi metric)
  fall out of the same one-hot for free.

The combine kernel is the transpose: a weighted destination multi-hot matmul
gathering expert outputs back to token rows.  Both kernels take a
per-assignment weight operand, which makes them each other's VJP (see
``ops.py``): d(dispatch)/dx is a combine, d(combine)/dbuf is a dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dispatch_kernel(v_ref, w_ref, slot_ref, valid_ref,
                     buf_ref, rank_ref, keep_ref, routed_ref, kept_ref,
                     *, k: int, bt: int, s: int, cap: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        buf_ref[...] = jnp.zeros_like(buf_ref)
        routed_ref[...] = jnp.zeros_like(routed_ref)
        kept_ref[...] = jnp.zeros_like(kept_ref)

    n = bt * k
    slot = slot_ref[...].reshape(n)
    valid = valid_ref[...].reshape(n) != 0
    s_eff = jnp.where(valid, slot, s)              # invalid -> virtual seg
    iota_s = jax.lax.broadcasted_iota(jnp.int32, (n, s + 1), 1)
    oh = (s_eff[:, None] == iota_s).astype(jnp.int32)          # [N, S+1]
    base = jnp.concatenate([routed_ref[...], jnp.zeros((1,), jnp.int32)])
    excl = jnp.cumsum(oh, axis=0) - oh             # exclusive, within block
    rank = ((base[None, :] + excl) * oh).sum(1)    # [N]
    keep = valid & (rank < cap)
    rank = jnp.where(valid, rank, 0)   # invalid ranks are meaningless (the
    #                                    virtual segment's base isn't carried)
    routed_ref[...] += oh[:, :s].sum(0)
    kept_ref[...] += (oh[:, :s] * keep[:, None].astype(jnp.int32)).sum(0)
    rank_ref[...] = rank.reshape(bt, k)
    keep_ref[...] = keep.astype(jnp.int32).reshape(bt, k)

    # destination multi-hot [bt, S*C] -> MXU scatter into the VMEM buffer
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (n, cap), 1)
    ohc = ((rank[:, None] == iota_c) & keep[:, None]).astype(jnp.float32)
    wm = w_ref[...].reshape(n).astype(jnp.float32)
    dm = (oh[:, :s].astype(jnp.float32)[:, :, None] * ohc[:, None, :])
    dm = (dm * wm[:, None, None]).reshape(bt, k, s * cap).sum(1)
    upd = jax.lax.dot_general(
        dm, v_ref[...].astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    buf_ref[...] += upd.reshape(s, cap, v_ref.shape[-1]).astype(buf_ref.dtype)


def _combine_kernel(buf_ref, w_ref, slot_ref, rank_ref, keep_ref, y_ref,
                    *, k: int, bt: int, s: int, cap: int):
    n = bt * k
    slot = slot_ref[...].reshape(n)
    rank = rank_ref[...].reshape(n)
    keep = keep_ref[...].reshape(n) != 0
    iota_s = jax.lax.broadcasted_iota(jnp.int32, (n, s), 1)
    oh = ((slot[:, None] == iota_s) & keep[:, None]).astype(jnp.float32)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (n, cap), 1)
    ohc = ((rank[:, None] == iota_c) & keep[:, None]).astype(jnp.float32)
    wm = w_ref[...].reshape(n).astype(jnp.float32)
    dm = (oh[:, :, None] * ohc[:, None, :]) * wm[:, None, None]
    dm = dm.reshape(bt, k, s * cap).sum(1)                      # [bt, S*C]
    y = jax.lax.dot_general(
        dm, buf_ref[...].reshape(s * cap, buf_ref.shape[-1]).astype(
            jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def dispatch_pallas(v, w, slot, valid, n_slots: int, cap: int,
                    bt: int = 256, interpret: bool = True):
    """v [T,D]; w/slot/valid [T,k] -> (buf [S,C,D], rank, keep [T,k] i32,
    routed [S] i32, kept [S] i32).  Grid walks token blocks sequentially;
    the routed histogram doubles as the cross-block rank base."""
    t, d = v.shape
    k = slot.shape[1]
    bt = min(bt, t)
    assert t % bt == 0, (t, bt)
    kern = functools.partial(_dispatch_kernel, k=k, bt=bt, s=n_slots, cap=cap)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((n_slots, cap, d), v.dtype),
                   jax.ShapeDtypeStruct((t, k), jnp.int32),
                   jax.ShapeDtypeStruct((t, k), jnp.int32),
                   jax.ShapeDtypeStruct((n_slots,), jnp.int32),
                   jax.ShapeDtypeStruct((n_slots,), jnp.int32)),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0)),
                  pl.BlockSpec((bt, k), lambda i: (i, 0)),
                  pl.BlockSpec((bt, k), lambda i: (i, 0)),
                  pl.BlockSpec((bt, k), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((n_slots, cap, d), lambda i: (0, 0, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((n_slots,), lambda i: (0,)),
                   pl.BlockSpec((n_slots,), lambda i: (0,))),
        interpret=interpret,
    )(v, w, slot, valid)


def combine_pallas(buf, w, slot, rank, keep, bt: int = 256,
                   interpret: bool = True):
    """buf [S,C,D]; w [T,k] f32; slot/rank/keep [T,k] i32 -> y [T,D]."""
    s, cap, d = buf.shape
    t, k = slot.shape
    bt = min(bt, t)
    assert t % bt == 0, (t, bt)
    kern = functools.partial(_combine_kernel, k=k, bt=bt, s=s, cap=cap)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((t, d), buf.dtype),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((s, cap, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((bt, k), lambda i: (i, 0)),
                  pl.BlockSpec((bt, k), lambda i: (i, 0)),
                  pl.BlockSpec((bt, k), lambda i: (i, 0)),
                  pl.BlockSpec((bt, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        interpret=interpret,
    )(buf, w, slot, rank, keep)
