"""Pure-jnp oracle for the fused MoE dispatch/combine kernel family.

Implements the *fused* dispatch algorithm (the one the Pallas kernel runs) in
vectorized jnp, so it doubles as the fast off-TPU execution path:

* **in-segment rank without a sort** — the XLA baseline in
  ``models.moe.dispatch_combine`` ranks assignments inside their slot segment
  via stable ``argsort`` + ``searchsorted``; for a stable sort that rank is
  exactly "number of earlier assignments (in flat T*k order) with the same
  slot", i.e. an exclusive running histogram.  We compute it directly from an
  exclusive cumsum of the slot one-hot — bit-identical ranks, no sort.
* **capacity mask** — ``keep = valid & (rank < cap)``; identical drop
  decisions to the baseline by construction.
* **bucketed scatter / weighted gather** — each kept assignment owns a unique
  ``(slot, rank)`` bucket, so scatter-add is single-writer and the combine is
  a plain gather + per-token weighted reduction.

The Reshape load metrics (routed counts phi, kept counts, drops) fall out of
the same one-hot, matching the baseline's metrics exactly.
"""
from __future__ import annotations

import jax.numpy as jnp


def dispatch_ref(v, w, slot, valid, n_slots: int, cap: int):
    """v [T,D]; w/slot/valid [T,k] (w f32 per-assignment scale, valid i32).

    Returns (buf [S,C,D], rank [T,k] i32, keep [T,k] i32, routed [S] i32,
    kept [S] i32).  ``buf[s, c] = w * v[tok]`` for the kept assignment ranked
    ``c`` in slot ``s`` (zeros where unfilled).
    """
    t, d = v.shape
    k = slot.shape[1]
    n = t * k
    flat_slot = slot.reshape(n)
    flat_valid = valid.reshape(n) != 0
    # invalid assignments rank in a virtual segment past n_slots-1, exactly
    # like the baseline's sort-to-the-end trick
    s_eff = jnp.where(flat_valid, flat_slot, n_slots)
    oh = (s_eff[:, None] == jnp.arange(n_slots + 1)[None, :]).astype(jnp.int32)
    rank = jnp.take_along_axis(jnp.cumsum(oh, 0) - oh, s_eff[:, None],
                               1)[:, 0]
    keep = flat_valid & (rank < cap)
    rank = jnp.where(flat_valid, rank, 0)   # invalid ranks are meaningless
    dest = jnp.where(keep, flat_slot * cap + rank, n_slots * cap)
    tok = jnp.repeat(jnp.arange(t), k)
    wm = (w.reshape(n) * keep).astype(v.dtype)
    buf = jnp.zeros((n_slots * cap + 1, d), v.dtype).at[dest].add(
        v[tok] * wm[:, None])
    routed = oh[:, :n_slots].sum(0)
    kept = (oh[:, :n_slots] * keep[:, None].astype(jnp.int32)).sum(0)
    return (buf[:-1].reshape(n_slots, cap, d),
            rank.reshape(t, k).astype(jnp.int32),
            keep.reshape(t, k).astype(jnp.int32), routed, kept)


def combine_ref(buf, w, slot, rank, keep):
    """buf [S,C,D]; w [T,k] f32; slot/rank/keep [T,k] i32 -> y [T,D].

    ``y[t] = sum_j w[t,j] * keep[t,j] * buf[slot[t,j], rank[t,j]]``.
    """
    s, cap, d = buf.shape
    t, k = slot.shape
    n = t * k
    kb = keep.reshape(n) != 0
    dest = jnp.where(kb, slot.reshape(n) * cap + rank.reshape(n), 0)
    gathered = buf.reshape(s * cap, d)[dest]
    wm = (w.reshape(n) * kb).astype(buf.dtype)
    tok = jnp.repeat(jnp.arange(t), k)
    return jnp.zeros((t, d), buf.dtype).at[tok].add(gathered * wm[:, None])
