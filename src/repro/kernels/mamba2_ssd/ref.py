"""Sequential (exact) Mamba2 SSD recurrence — the numerical oracle.

Per (batch, head), state h [P, N] (P = head dim, N = d_state):
    h_t = exp(dt_t * A) h_{t-1} + (dt_t * x_t) B_t^T
    y_t = h_t C_t + D * x_t
A < 0 scalar per head; B, C shared across heads (n_groups = 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba2_ref(x, dt, a, bm, c, d, h0=None):
    """x [B,H,T,P]; dt [B,H,T]; a [H]; bm,c [B,T,N]; d [H].
    Returns (y [B,H,T,P], hT [B,H,P,N])."""
    b, h, t, p = x.shape
    n = bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    f32 = jnp.float32

    def step(hs, inp):
        xt, dtt, bt, ct = inp                    # [B,H,P],[B,H],[B,N],[B,N]
        decay = jnp.exp(dtt * a[None])           # [B,H]
        hs = hs * decay[..., None, None] + \
            (dtt[..., None] * xt)[..., :, None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", hs, ct)
        return hs, y

    xs = x.transpose(2, 0, 1, 3).astype(f32)
    dts = dt.transpose(2, 0, 1).astype(f32)
    bs = bm.transpose(1, 0, 2).astype(f32)
    cs = c.transpose(1, 0, 2).astype(f32)
    hT, ys = jax.lax.scan(step, h0.astype(f32), (xs, dts, bs, cs))
    y = ys.transpose(1, 2, 0, 3) + d[None, :, None, None] * x.astype(f32)
    return y.astype(x.dtype), hT
