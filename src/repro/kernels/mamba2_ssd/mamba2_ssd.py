"""Pallas TPU kernel: chunked Mamba2 SSD.

Grid = (B*H, T/Q), chunk dim sequential; [P,N] state in VMEM scratch.  The
intra-chunk work is a [Q,Q] decay-masked attention (C B^T ⊙ L) plus two MXU
matmuls — per-step VMEM = Q*(P+2N) inputs + P*N state + Q*Q mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xd_ref, la_ref, b_ref, c_ref, h0_ref, y_ref, hT_ref, h_scr,
            *, q: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[...]

    xb = xd_ref[...].astype(jnp.float32)       # [Q,P] (dt-weighted)
    lb = la_ref[...].astype(jnp.float32)       # [Q,1] log decay per step
    bb = b_ref[...].astype(jnp.float32)        # [Q,N]
    cb = c_ref[...].astype(jnp.float32)        # [Q,N]
    hs = h_scr[...]                            # [P,N]

    la = jnp.cumsum(lb[:, 0], axis=0)          # [Q]
    seg = la[:, None] - la[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(col <= row, jnp.exp(seg), 0.0)
    att = jnp.dot(cb, bb.T, preferred_element_type=jnp.float32) * L
    y = jnp.dot(att, xb, preferred_element_type=jnp.float32)
    y = y + jnp.exp(la)[:, None] * jnp.dot(cb, hs.T,
                                           preferred_element_type=jnp.float32)
    la_q = la[-1]
    x_dec = xb * jnp.exp(la_q - la)[:, None]
    hs_new = jnp.exp(la_q) * hs + jnp.dot(x_dec.T, bb,
                                          preferred_element_type=jnp.float32)
    h_scr[...] = hs_new
    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        hT_ref[...] = hs_new


def mamba2_pallas(x, dt, a, bm, c, d, h0=None, chunk: int = 128,
                  interpret=True):
    b, h, t, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, t)
    assert t % q == 0
    nc = t // q
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    bh = b * h
    f32 = jnp.float32
    xd = (x.astype(f32) * dt[..., None].astype(f32)).reshape(bh, t, p)
    la = (dt.astype(f32) * a[None, :, None]).reshape(bh, t, 1)
    bf = jnp.broadcast_to(bm.astype(f32)[:, None], (b, h, t, n)).reshape(bh, t, n)
    cf = jnp.broadcast_to(c.astype(f32)[:, None], (b, h, t, n)).reshape(bh, t, n)
    h0f = h0.reshape(bh, p, n).astype(f32)

    kern = functools.partial(_kernel, q=q, nc=nc)
    y, hT = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((bh, t, p), x.dtype),
                   jax.ShapeDtypeStruct((bh, p, n), f32)),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((None, q, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, q, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, p, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((None, q, p), lambda i, j: (i, j, 0)),
                   pl.BlockSpec((None, p, n), lambda i, j: (i, 0, 0))),
        scratch_shapes=[pltpu.VMEM((p, n), f32)],
        interpret=interpret,
    )(xd, la, bf, cf, h0f)
    y = y.reshape(b, h, t, p) + d[None, :, None, None].astype(f32) * x.astype(f32)
    return y.astype(x.dtype), hT.reshape(b, h, p, n)
