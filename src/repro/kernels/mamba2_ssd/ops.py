"""Chunked Mamba2 SSD — jnp implementation + Pallas dispatch.

Mamba2's scalar-per-head decay makes the chunked form exact (the decay matrix
L[t,s] = exp(la_t - la_s) is always <= 1 on the causal triangle — no clamp
needed, unlike RWKV6's per-channel decay).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba2_chunked(x, dt, a, bm, c, d, h0=None, chunk: int = 128):
    """Shapes as in ref.  Returns (y [B,H,T,P], hT [B,H,P,N])."""
    b, h, t, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, t)
    assert t % q == 0
    nc = t // q
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    f32 = jnp.float32

    xd = (x.astype(f32) * dt[..., None].astype(f32))          # dt-weighted x
    la_step = dt.astype(f32) * a[None, :, None]               # log decay/step

    xc = xd.reshape(b, h, nc, q, p).transpose(2, 0, 1, 3, 4)
    lc = la_step.reshape(b, h, nc, q).transpose(2, 0, 1, 3)
    bc = jnp.broadcast_to(bm.astype(f32)[:, None], (b, h, t, n)) \
        .reshape(b, h, nc, q, n).transpose(2, 0, 1, 3, 4)
    cc = jnp.broadcast_to(c.astype(f32)[:, None], (b, h, t, n)) \
        .reshape(b, h, nc, q, n).transpose(2, 0, 1, 3, 4)

    tri = jnp.tril(jnp.ones((q, q), bool))                    # incl. diagonal

    def body(hs, inp):
        xb, lb, bb, cb = inp                                  # per-chunk
        la = jnp.cumsum(lb, axis=-1)                          # [B,H,Q]
        seg = la[..., :, None] - la[..., None, :]             # [B,H,Q,Q]
        L = jnp.where(tri[None, None], jnp.exp(seg), 0.0)
        att = jnp.einsum("bhqn,bhsn->bhqs", cb, bb) * L
        y = jnp.einsum("bhqs,bhsp->bhqp", att, xb)
        y = y + jnp.exp(la)[..., None] * jnp.einsum("bhpn,bhqn->bhqp", hs, cb)
        la_q = la[..., -1:]
        x_dec = xb * jnp.exp(la_q - la)[..., None]
        hs_new = jnp.exp(la_q)[..., None] * hs + jnp.einsum(
            "bhqp,bhqn->bhpn", x_dec, bb)
        return hs_new, y

    hT, ys = jax.lax.scan(body, h0.astype(f32), (xc, lc, bc, cc))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, t, p)
    y = y + d[None, :, None, None] * x.astype(f32)
    return y.astype(x.dtype), hT


def mamba2_decode_step(xt, dtt, a, bt, ct, d, hs):
    """One-token update.  xt [B,H,P]; dtt [B,H]; bt,ct [B,N]; hs [B,H,P,N]."""
    f32 = jnp.float32
    decay = jnp.exp(dtt.astype(f32) * a[None])
    hs = hs * decay[..., None, None] + \
        (dtt[..., None].astype(f32) * xt.astype(f32))[..., :, None] * \
        bt.astype(f32)[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", hs, ct.astype(f32)) + \
        d[None, :, None] * xt.astype(f32)
    return y.astype(xt.dtype), hs


def mamba2(x, dt, a, bm, c, d, h0=None, chunk: int = 128, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return mamba2_chunked(x, dt, a, bm, c, d, h0, chunk)
    from repro.kernels.mamba2_ssd.mamba2_ssd import mamba2_pallas
    return mamba2_pallas(x, dt, a, bm, c, d, h0, chunk=chunk,
                         interpret=(impl == "interpret"))
