"""Sequential (exact) RWKV6 WKV recurrence — the numerical oracle.

State S [N_k, N_v] per (batch, head):
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with data-dependent per-channel decay w_t in (0, 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, w, u, s0=None):
    """r,k,v,w [B,H,T,N]; u [H,N]; s0 [B,H,N,N].  Returns (y [B,H,T,N], sT)."""
    b, h, t, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, n, n), jnp.float32)

    uf = u[None].astype(jnp.float32)               # [1,H,N]

    def step(s, inp):
        rt, kt, vt, wt = inp                       # [B,H,N] each
        y = jnp.einsum("bhn,bhnm->bhm", rt, s) + \
            (rt * uf * kt).sum(-1, keepdims=True) * vt
        s_new = wt[..., :, None] * s + kt[..., :, None] * vt[..., None, :]
        return s_new, y

    rs = r.transpose(2, 0, 1, 3).astype(jnp.float32)
    ks = k.transpose(2, 0, 1, 3).astype(jnp.float32)
    vs = v.transpose(2, 0, 1, 3).astype(jnp.float32)
    ws = w.transpose(2, 0, 1, 3).astype(jnp.float32)
    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), (rs, ks, vs, ws))
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), sT
