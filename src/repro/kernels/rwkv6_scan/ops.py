"""Chunked RWKV6 — jnp implementation (dry-run path + kernel oracle at scale)
and the Pallas dispatch.

TPU adaptation of the GPU per-thread recurrence: the sequence is split into
chunks of Q tokens; within a chunk the recurrence becomes dense matmuls
(MXU work) — an intra-chunk "attention" with decay-weighted keys — and the
state is carried across chunks.  Exponent factoring uses the clamp trick
(exact when cumulative in-chunk decay stays above e^-CLAMP; see kernel tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CLAMP = 30.0


def rwkv6_chunked(r, k, v, w, u, s0=None, chunk: int = 64):
    """r,k,v,w [B,H,T,N]; u [H,N].  Returns (y [B,H,T,N], sT [B,H,N,N])."""
    b, h, t, n = r.shape
    q = min(chunk, t)
    assert t % q == 0, (t, q)
    nc = t // q
    if s0 is None:
        s0 = jnp.zeros((b, h, n, n), jnp.float32)
    f32 = jnp.float32

    def reshape(x):
        return x.astype(f32).reshape(b, h, nc, q, n).transpose(2, 0, 1, 3, 4)

    rc, kc, vc, wc = map(reshape, (r, k, v, w))
    uf = u.astype(f32)[None]                        # [1,H,N]
    tri_strict = jnp.tril(jnp.ones((q, q), bool), -1)

    def body(s, inp):
        rb, kb, vb, wb = inp                        # [B,H,Q,N]
        la = jnp.cumsum(jnp.log(wb), axis=2)        # inclusive cumulative
        la_prev = jnp.pad(la, ((0, 0),) * 2 + ((1, 0), (0, 0)))[:, :, :-1]
        q_t = rb * jnp.exp(la_prev)                 # decayed receptance
        k_t = kb * jnp.exp(jnp.minimum(-la, CLAMP))
        att = jnp.einsum("bhqn,bhsn->bhqs", q_t, k_t)
        att = jnp.where(tri_strict[None, None], att, 0.0)
        y = jnp.einsum("bhqs,bhsn->bhqn", att, vb)
        # current-token bonus term
        y = y + (rb * uf[:, :, None] * kb).sum(-1, keepdims=True) * vb
        # contribution from the carried state
        y = y + jnp.einsum("bhqn,bhnm->bhqm", q_t, s)
        # state update: S' = diag(exp(la_Q)) S + sum_s (k_s*exp(la_Q-la_s)) v_s^T
        la_q = la[:, :, -1:, :]
        k_dec = kb * jnp.exp(la_q - la)
        s_new = jnp.exp(la_q[:, :, 0, :, None]) * s + jnp.einsum(
            "bhqn,bhqm->bhnm", k_dec, vb)
        return s_new, y

    sT, ys = jax.lax.scan(body, s0.astype(f32), (rc, kc, vc, wc))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, t, n)
    return y.astype(r.dtype), sT


def rwkv6_decode_step(rt, kt, vt, wt, u, s):
    """One-token state update (serve path).  rt..wt [B,H,N]; s [B,H,N,N]."""
    y = jnp.einsum("bhn,bhnm->bhm", rt.astype(jnp.float32), s) + \
        (rt * u[None] * kt).sum(-1, keepdims=True).astype(jnp.float32) * \
        vt.astype(jnp.float32)
    s_new = wt.astype(jnp.float32)[..., :, None] * s + \
        kt.astype(jnp.float32)[..., :, None] * vt.astype(jnp.float32)[..., None, :]
    return y.astype(rt.dtype), s_new


def rwkv6(r, k, v, w, u, s0=None, chunk: int = 64, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return rwkv6_chunked(r, k, v, w, u, s0, chunk)
    from repro.kernels.rwkv6_scan.rwkv6_scan import rwkv6_pallas
    return rwkv6_pallas(r, k, v, w, u, s0, chunk=chunk,
                        interpret=(impl == "interpret"))
