"""Pallas TPU kernel: chunked RWKV6 WKV scan.

Grid = (B*H, T/Q) with the chunk dimension iterated sequentially (TPU grid
order) so the [N,N] state lives in a VMEM scratch across chunk steps.  Each
step does three MXU matmuls (att = q~ k~^T, y = att v + q~ S, S update) on a
[Q,N] tile — VMEM footprint = 4 Q*N input tiles + N*N state + Q*Q att.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CLAMP = 30.0


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_scr,
            *, q: int, n: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[...]

    rb = r_ref[...].astype(jnp.float32)       # [Q,N]
    kb = k_ref[...].astype(jnp.float32)
    vb = v_ref[...].astype(jnp.float32)
    wb = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)        # [1,N]
    s = s_scr[...]

    la = jnp.cumsum(jnp.log(wb), axis=0)
    la_prev = la - jnp.log(wb)                # exclusive cumulative
    q_t = rb * jnp.exp(la_prev)
    k_t = kb * jnp.exp(jnp.minimum(-la, CLAMP))
    att = jnp.dot(q_t, k_t.T, preferred_element_type=jnp.float32)
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    att = jnp.where(col < row, att, 0.0)
    y = jnp.dot(att, vb, preferred_element_type=jnp.float32)
    y = y + (rb * u * kb).sum(-1, keepdims=True) * vb
    y = y + jnp.dot(q_t, s, preferred_element_type=jnp.float32)

    la_q = la[-1:, :]                          # [1,N]
    k_dec = kb * jnp.exp(la_q - la)
    s_new = jnp.exp(la_q).T * s + jnp.dot(k_dec.T, vb,
                                          preferred_element_type=jnp.float32)
    s_scr[...] = s_new
    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        sT_ref[...] = s_new


def rwkv6_pallas(r, k, v, w, u, s0=None, chunk: int = 64, interpret=True):
    """r,k,v,w [B,H,T,N]; u [H,N]; s0 [B,H,N,N] -> (y, sT)."""
    b, h, t, n = r.shape
    q = min(chunk, t)
    assert t % q == 0
    nc = t // q
    if s0 is None:
        s0 = jnp.zeros((b, h, n, n), jnp.float32)
    bh = b * h
    rf, kf, vf, wf = (x.reshape(bh, t, n) for x in (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (b, h, n)).reshape(bh, 1, n)
    s0f = s0.reshape(bh, n, n).astype(jnp.float32)

    kern = functools.partial(_kernel, q=q, n=n, nc=nc)
    y, sT = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((bh, t, n), r.dtype),
                   jax.ShapeDtypeStruct((bh, n, n), jnp.float32)),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((None, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, 1, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, n, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((None, q, n), lambda i, j: (i, j, 0)),
                   pl.BlockSpec((None, n, n), lambda i, j: (i, 0, 0))),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0f)
    return y.reshape(b, h, t, n), sT.reshape(b, h, n, n)
