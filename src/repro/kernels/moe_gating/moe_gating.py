"""Pallas TPU kernel: fused MoE gating (softmax + top-k + load histogram).

This fuses the Reshape metric collection (per-expert routed-token counts, the
workload metric phi of paper §3.2) into the router itself: the histogram is
accumulated in a VMEM-resident [E] output across grid steps, so skew detection
costs zero extra passes (vs the paper's reported 1–2 % metric overhead).
Top-k is K iterations of (max, mask) over the row block — K is small (<=8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, w_ref, e_ref, cnt_ref, *, k: int, bt: int, e: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = logits_ref[...].astype(jnp.float32)            # [bt, E]
    x = x - x.max(-1, keepdims=True)
    p = jnp.exp(x)
    probs = p / p.sum(-1, keepdims=True)

    iota_e = jax.lax.broadcasted_iota(jnp.int32, (bt, e), 1)
    remaining = probs
    ws, es, hist = [], [], jnp.zeros((e,), jnp.int32)
    for _ in range(k):
        m = remaining.max(-1)
        idx = jnp.argmax(remaining, -1).astype(jnp.int32)
        onehot = (iota_e == idx[:, None])
        remaining = jnp.where(onehot, -1.0, remaining)
        ws.append(m)
        es.append(idx)
        hist = hist + onehot.astype(jnp.int32).sum(0)
    w = jnp.stack(ws, -1)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    w_ref[...] = w.astype(w_ref.dtype)
    e_ref[...] = jnp.stack(es, -1)
    cnt_ref[...] += hist


def gating_pallas(logits, k: int, bt: int = 256, interpret=True):
    """logits [T,E] -> (weights [T,k] f32, experts [T,k] i32, counts [E] i32)."""
    t, e = logits.shape
    bt = min(bt, t)
    assert t % bt == 0, (t, bt)
    kern = functools.partial(_kernel, k=k, bt=bt, e=e)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((t, k), jnp.float32),
                   jax.ShapeDtypeStruct((t, k), jnp.int32),
                   jax.ShapeDtypeStruct((e,), jnp.int32)),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((e,), lambda i: (0,))),
        interpret=interpret,
    )(logits)
