"""Pure-jnp oracle for fused MoE gating: softmax + top-k + load histogram."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gating_ref(logits, k: int):
    """logits [T,E] -> (weights [T,k], experts [T,k] i32, counts [E] i32).

    weights are the re-normalized top-k softmax probabilities; counts is the
    Reshape load metric phi (tokens routed per expert, pre-capacity).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    weights = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    e = logits.shape[-1]
    counts = jnp.zeros((e,), jnp.int32).at[top_e.reshape(-1)].add(1)
    return weights, top_e.astype(jnp.int32), counts
