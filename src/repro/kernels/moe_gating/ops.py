"""Dispatch wrapper for fused MoE gating."""
from __future__ import annotations

import jax

from repro.kernels.moe_gating.moe_gating import gating_pallas
from repro.kernels.moe_gating.ref import gating_ref


def gating(logits, k: int, impl: str = "auto", bt: int = 256):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return gating_ref(logits, k)
    return gating_pallas(logits, k, bt=bt, interpret=(impl == "interpret"))
