"""Pure-jnp oracle for flash attention: naive materialized softmax."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None, q_offset: int = 0):
    """q [B,H,Sq,hd]; k,v [B,H,Sk,hd] (heads already repeated)."""
    sq, sk = q.shape[2], k.shape[2]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
