"""Jit'd dispatch wrapper: pallas kernel (TPU), interpret (CPU validation),
or the chunked-jnp path (what the CPU dry-run lowers)."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.models.attention import chunked_attention


def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    impl: str = "auto"):
    """q [B,Sq,H,hd]; k,v [B,Sk,KH,hd].  Returns [B,Sq,H,hd]."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    from repro.models.attention import repeat_kv
    h, kh = q.shape[2], k.shape[2]
    kr = repeat_kv(k, h // kh).transpose(0, 2, 1, 3)
    vr = repeat_kv(v, h // kh).transpose(0, 2, 1, 3)
    out = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), kr, vr, causal=causal, window=window,
        q_offset=q_offset, interpret=(impl == "interpret"))
    return out.transpose(0, 2, 1, 3)
