"""Pallas TPU flash attention (blocked online softmax).

TPU adaptation: the GPU version streams KV through shared memory per thread
block; here each grid step owns a (bq x hd) query tile resident in VMEM and
loops over (bk x hd) KV tiles with an online-softmax carry held in VMEM
scratch.  Tile sizes are MXU-aligned (128) and sized so the working set
(q tile + 2 kv tiles + acc) stays well under the ~16 MB VMEM budget.
Supports causal and sliding-window masks (gemma3 local layers).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, sk: int,
            causal: bool, window: Optional[int], q_offset: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale        # [bq, hd]
    n_kv = sk // bk

    def body(kv_i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kv_i * bk, bk), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kv_i * bk, bk), slice(None)))
        logits = jnp.dot(q, k.astype(jnp.float32).T,
                         preferred_element_type=jnp.float32)   # [bq, bk]
        q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (k_pos > q_pos - window)
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q_ref.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None, q_offset=0,
                           bq=128, bk=128, interpret=True):
    """q [B,H,Sq,hd]; k,v [B,H,Sk,hd] (kv heads pre-repeated).  -> [B,H,Sq,hd]"""
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = hd ** -0.5
    qf = q.reshape(b * h, sq, hd)
    kf = k.reshape(b * h, sk, hd)
    vf = v.reshape(b * h, sk, hd)
    kern = functools.partial(_kernel, bq=bq, bk=bk, sk=sk, causal=causal,
                             window=window, q_offset=q_offset, scale=scale)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, sk, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, sk, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd)
