"""End-to-end driver: train the paper-technique showcase MoE LM for a few
hundred steps with live Reshape expert-skew mitigation, printing the load
balance + dropped-token trajectory (the 'results shown to the user').

  PYTHONPATH=src python examples/train_moe_reshape.py [--steps 300]

This is the CPU-scale version of the run; on a pod the same TrainLoop drives
the jit'd production step (see repro/launch/train.py and the dry-run).
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_arch
from repro.configs.base import reduced
from repro.core.reshape_moe import MoEReshaper
from repro.core.skew import SkewParams
from repro.data.synthetic import TokenStream
from repro.optim.adamw import AdamWCfg
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.runtime.train import TrainHyper

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--no-reshape", action="store_true")
args = ap.parse_args()

# ~8M-param reduction of the 100M paper config (CPU-friendly); use
# --arch paper-moe-100m with repro.launch.train for the full one.
cfg = reduced(get_arch("paper-moe-100m"), layers=4, d_model=128, vocab=2048)
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.1))
print(f"params ~{cfg.n_params() / 1e6:.1f}M  experts={cfg.moe.num_experts} "
      f"top-{cfg.moe.top_k}")

stream = TokenStream(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0,
                     class_alpha=2.0)          # skewed token classes
reshaper = None
if not args.no_reshape:
    reshaper = MoEReshaper(cfg, n_moe_layers=4, ep_ranks=2,
                           params=SkewParams(eta=0.0, tau=0.15),
                           phase1_steps=1)
loop = TrainLoop(cfg, stream,
                 TrainHyper(opt=AdamWCfg(lr=1e-3, warmup_steps=30,
                                         total_steps=args.steps)),
                 LoopConfig(microbatches=2), reshaper=reshaper)
hist = loop.run(args.steps)

for h in hist[:: max(1, len(hist) // 25)]:
    sc = h.get("slot_counts")
    lb = ""
    if sc is not None:
        per_rank = sc.reshape(sc.shape[0], 2, -1).sum(-1)
        lb = f"  rank_lb={per_rank.min() / max(per_rank.max(), 1):.2f}"
    print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
          f"dropped {int(h.get('dropped', np.zeros(1)).sum()):5d}{lb}")

first = np.mean([h["loss"] for h in hist[:10]])
last = np.mean([h["loss"] for h in hist[-10:]])
print(f"\nloss {first:.4f} -> {last:.4f}")
if reshaper:
    print(f"reshape: {reshaper.iterations} mitigation iterations, "
          f"{len(reshaper.events)} events")
