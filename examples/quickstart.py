"""Quickstart: build a model from the registry, run one train step and a
few decode steps — the public API in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import ShapeCfg
from repro.models import lm
from repro.runtime.train import TrainHyper, build_train_step, make_state
from repro.runtime.serve import BatchedServer

# 1. pick an architecture (any of the 10 assigned ids, or *-smoke reductions)
cfg = get_arch("gemma3-1b-smoke")
print(f"arch={cfg.name}  layers={cfg.num_layers}  pattern={cfg.pattern[:6]}…")

# 2. one training step
shape = ShapeCfg("demo", seq_len=32, global_batch=4, kind="train",
                 microbatches=2)
state = make_state(cfg, jax.random.PRNGKey(0))
step = jax.jit(build_train_step(cfg, shape, TrainHyper()))
tokens = jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab, (4, 32)), jnp.int32)
ps = jnp.zeros((1, 1, 1), jnp.int32)
pc = jnp.ones((1, 1, 1), jnp.float32)
state, metrics = step(state, {"tokens": tokens}, ps, pc)
print(f"loss={float(metrics['loss']):.3f}  "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

# 3. batched serving (prefill + decode with KV cache)
srv = BatchedServer(cfg, state["params"], max_len=64)
prompts = np.random.default_rng(1).integers(1, cfg.vocab, (2, 8)).astype(
    np.int32)
out = srv.generate(prompts, max_new=8, temperature=0.0)
print(f"generated: {out.tolist()}")
