"""Amber interactivity demo: pause a running training job, inspect state
WHILE paused, hot-update the learning rate, set a breakpoint, resume —
then crash it and recover bit-exact from checkpoint + control-replay log.

  PYTHONPATH=src python examples/interactive_control.py
"""
import shutil
import threading
import time

import numpy as np

from repro.configs import get_arch
from repro.core import messages as M
from repro.core.breakpoints import GlobalCountBreakpoint
from repro.data.synthetic import TokenStream
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.runtime.train import TrainHyper

CKPT = "/tmp/repro_interactive_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_arch("olmoe-1b-7b-smoke")
stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=1)
loop = TrainLoop(cfg, stream, TrainHyper(),
                 LoopConfig(microbatches=2, ckpt_every=4, ckpt_dir=CKPT))
ctl = loop.controller


def user_session():
    time.sleep(2.0)
    print("\n[user] >> pause")
    t0 = time.monotonic()
    r = ctl.send(M.pause()).wait(60)
    print(f"[user] paused at (step, microbatch)={r['paused_at']} "
          f"in {(time.monotonic() - t0) * 1e3:.0f} ms")
    info = ctl.send(M.inspect()).wait(60)     # responsive WHILE paused
    print(f"[user] inspect while paused: step={info['step']} "
          f"loss_tail={[round(h['loss'], 3) for h in info['history_tail']]}")
    print("[user] >> update lr_scale=0.3  (hot reconfiguration)")
    ctl.send(M.update(lr_scale=0.3)).wait(60)
    print("[user] >> set breakpoint: pause after 1,000 more tokens")
    ctl.send(M.set_breakpoint(GlobalCountBreakpoint(
        "token-budget", "tokens", target=1000))).wait(60)
    print("[user] >> resume")
    ctl.send(M.resume()).wait(60)
    # keep watching: when the token-budget breakpoint pauses the run,
    # resume it so training finishes (timing-robust — the breakpoint may
    # fire at any step depending on machine speed)
    while not done.is_set():
        if loop.hit_breakpoints and ctl.paused:
            print("[user] breakpoint hit -> resume to finish")
            ctl.send(M.resume()).wait(60)
            return
        time.sleep(0.25)


done = threading.Event()
th = threading.Thread(target=user_session)
th.start()
hist = loop.run(16)
done.set()
th.join()
print(f"\nran {len(hist)} steps; lr_scale now {loop.lc.lr_scale}; "
      f"breakpoints hit: {loop.hit_breakpoints}")
print(f"control log: {[(r.kind, r.step, r.microbatch) for r in ctl.log]}")
step_costs = {k: round(v, 4) for k, v in loop.engine.costs.snapshot().items()
              if k.startswith("train")}
print(f"engine jobs: {loop.engine.jobs_run}; measured step costs (s): "
      f"{step_costs}")
print(f"step-path decisions tail: "
      f"{[d['choice'] for d in list(loop.engine.decisions)[-5:]]} "
      f"(granulated while interactivity was live, fused while idle)")

# ---- crash & recover ------------------------------------------------------
print("\nsimulating crash; recovering from checkpoint + control-replay log…")
stream2 = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=1)
rec = TrainLoop.recover(cfg, stream2, TrainHyper(),
                        LoopConfig(microbatches=2, ckpt_every=4,
                                   ckpt_dir=CKPT))
print(f"recovered at step {int(rec.state['step'])}; replaying "
      f"{len(rec.controller._replay)} logged control messages…")
rec.run(16 - int(rec.state["step"]))
match = all(np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(*(map(lambda s: __import__('jax').tree.leaves(
                s['params']), (loop.state, rec.state)))))
print(f"post-recovery params identical to uninterrupted run: {match}")
