"""Continuous-batching serving through the engine layer: mixed-length
requests join/evict a slot pool, prefill runs in chunked batches, tick
composition is the Maestro min-FRT choice — and the stream answers
pause/inspect/update control messages MID-GENERATION, just like training.

  PYTHONPATH=src python examples/serve_batched.py
"""
import threading
import time

import numpy as np
import jax

from repro.configs import get_arch
from repro.core import messages as M
from repro.core.regions import Op, Workflow, schedule
from repro.engine import ServeEngine, serve_tick_workflow
from repro.models import lm
from repro.runtime.serve import BatchedServer

rng = np.random.default_rng(0)

# ---- throughput: continuous batching vs the old static loop ---------------
for arch in ("yi-34b-smoke", "gemma3-1b-smoke", "rwkv6-1.6b-smoke"):
    cfg = get_arch(arch)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, max_len=96, slots=4,
                        prefill_chunk=16, decode_chunk=8)
    lens, news = [4, 12, 20, 28], [16, 8, 12, 6]
    prompts = [rng.integers(1, cfg.vocab, (l,)).astype(np.int32)
               for l in lens]
    eng = srv.engine()
    reqs = [eng.submit(p, max_new=n) for p, n in zip(prompts, news)]
    eng.run_until_done()                                  # warm the jits
    reqs = [eng.submit(p, max_new=n) for p, n in zip(prompts, news)]
    t0 = time.time()
    eng.run_until_done()
    dt = time.time() - t0
    print(f"{arch:24s} mixed plens={lens} max_new={news} "
          f"-> {sum(news)} tokens in {dt:.2f}s "
          f"({sum(news) / dt:.1f} tok/s, {eng.tick_no} ticks, "
          f"jobs={eng.engine.jobs_run})")

# ---- control plane mid-stream --------------------------------------------
cfg = get_arch("gemma3-1b-smoke")
params = lm.init(cfg, jax.random.PRNGKey(0))
eng = ServeEngine(cfg, params, max_len=96, slots=2, prefill_chunk=8,
                  decode_chunk=2)
ctl = eng.engine.controller
for i in range(4):
    eng.submit(rng.integers(1, cfg.vocab, (6 + 4 * i,)).astype(np.int32),
               max_new=24)


def user_session():
    time.sleep(0.3)
    print("\n[user] >> pause (mid-generation)")
    r = ctl.send(M.pause()).wait(60)
    print(f"[user] paused at tick {r['paused_at'][0]}")
    info = ctl.send(M.inspect()).wait(60)          # answered WHILE paused
    busy = [s for s in info["slots"] if s]
    print(f"[user] inspect while paused: tick={info['tick']} "
          f"queue={info['queue_depth']} slots={busy}")
    print(f"[user] engine costs: "
          f"{ {k: round(v, 4) for k, v in info['engine']['costs'].items()} }")
    print("[user] >> update max_prefill_defer=1 (hot reconfiguration)")
    ctl.send(M.update(max_prefill_defer=1)).wait(60)
    print("[user] >> resume")
    ctl.send(M.resume()).wait(60)


th = threading.Thread(target=user_session)
th.start()
eng.run_until_done()
th.join()
done = eng.tokens_out
print(f"\nstream finished under control: {done} tokens over {eng.tick_no} "
      f"ticks; decisions tail: "
      f"{[d['choice'] for d in list(eng.engine.decisions)[-6:]]}")

# ---- speculative in-tick decoding: the n-gram proposer --------------------
# a per-slot n-gram suffix table (living in the donated pool) drafts up to
# cfg.serve.spec_len tokens per decode tick; the tick scan verifies them and
# commits the longest accepted prefix (greedy outputs bit-identical).  The
# decode arm — plain vs one of the spec proposers — is an engine decision
# from measured per-arm acceptance + runtime EMAs.
eng = ServeEngine(cfg, params, max_len=160, slots=2, prefill_chunk=8,
                  decode_chunk=4, spec_decode=True)
# pin the arm on for the demo (auto mode lets the CostBook decide; the
# n-gram table only pays off on repetitive traffic — see bench_serve_spec);
# forcing it shows the acceptance machinery learning
_choose = eng.engine.choose_serve_tick
eng.engine.choose_serve_tick = lambda *a, **k: (
    "spec:ngram" if _choose(*a, **k) == "decode" and k.get("spec_len", 0) > 1
    else _choose(*a, **k))
for _ in range(2):
    eng.submit(np.random.default_rng(1).integers(
        1, cfg.vocab, (8,)).astype(np.int32), max_new=48)
eng.run_until_done()
acc = eng.spec_accepted / max(eng.spec_proposed, 1)
print(f"\nspeculative decode (ngram arm pinned on): {eng.spec_ticks} spec "
      f"ticks, acceptance={acc:.2f} ({eng.spec_accepted}/{eng.spec_proposed} "
      f"drafts); the auto decision from these measurements would be: "
      f"{[d['choice'] for d in list(eng.engine.decisions)[-2:]]}; "
      f"accept EMA keys: "
      f"{[k for k in eng.engine.costs.snapshot() if 'accept' in k]}")

# ---- speculative decoding: the draft-model proposer -----------------------
# the second proposer family member: a tiny independent draft model decodes
# ahead of the target (per-slot draft cache rows live in the donated pool,
# shadowing every arm so draft state always equals the committed stream).
# distill_draft trains it on the target's own greedy streams in seconds;
# update(draft_params=...) hot-republishes a fresher draft mid-stream, and
# because the target verifies every position a wrong/stale draft can only
# lower acceptance, never change tokens.  This is the arm that wins on
# non-repetitive traffic, where the n-gram table has nothing to match.
from repro.engine import distill_draft, small_draft_cfg

dcfg = small_draft_cfg(cfg)
train_prompts = [rng.integers(1, cfg.vocab, (8,)).astype(np.int32)
                 for _ in range(6)]
t0 = time.time()
dparams = distill_draft(cfg, params, dcfg, train_prompts, max_new=48,
                        steps=300)
print(f"\ndistilled {dcfg.name} in {time.time() - t0:.1f}s")
eng = ServeEngine(cfg, params, max_len=160, slots=2, prefill_chunk=8,
                  decode_chunk=4, spec_decode=True, draft_cfg=dcfg,
                  draft_params=dparams)
_choose = eng.engine.choose_serve_tick
eng.engine.choose_serve_tick = lambda *a, **k: (
    "spec:draft" if _choose(*a, **k) == "decode" and k.get("spec_len", 0) > 1
    else _choose(*a, **k))
for p in train_prompts[:2]:
    eng.submit(p, max_new=48)
eng.run_until_done()
st = eng.spec_arms.get("draft", {})
print(f"draft arm: {st.get('ticks', 0)} spec ticks, acceptance="
      f"{st.get('accepted', 0) / max(st.get('proposed', 1), 1):.2f} "
      f"({st.get('accepted', 0)}/{st.get('proposed', 0)} drafts)")
# hot-republish mid-stream: even a garbage draft cannot change outputs
ctl = eng.engine.controller
for p in train_prompts[2:4]:
    eng.submit(p, max_new=24)
eng.tick()
ctl.send(M.update(draft_params=jax.tree.map(lambda x: -x, dparams))).wait(60)
eng.run_until_done()
st = eng.spec_arms["draft"]
print(f"after garbage hot-swap: acceptance fell to "
      f"{st['accepted'] / max(st['proposed'], 1):.2f} cumulative — "
      f"throughput cost, never a correctness cost")

# ---- priority classes over multiple slot pools ----------------------------
# two traffic classes (interactive "hi" outweighs batch "lo" 8:1, lo's
# prefills may sit out at most 4 scheduled ticks) over two slot pools; the
# engine arbitrates every tick across both pools under weighted FRT
# (Engine.choose_serve_job) and the aging bound keeps lo starvation-free.
import dataclasses
from repro.configs.base import PriorityClass

cfg_prio = dataclasses.replace(cfg, serve=dataclasses.replace(
    cfg.serve, classes=(PriorityClass("hi", 8.0, 6),
                        PriorityClass("lo", 1.0, 4))))
eng = ServeEngine(cfg_prio, params, max_len=96, slots=2, pools=2,
                  prefill_chunk=8, decode_chunk=2)
lo = [eng.submit(rng.integers(1, cfg.vocab, (20,)).astype(np.int32),
                 max_new=24, priority="lo") for _ in range(2)]
for _ in range(2):
    eng.tick()                        # batch load is mid-flight...
hi = [eng.submit(rng.integers(1, cfg.vocab, (4,)).astype(np.int32),
                 max_new=8, priority="hi") for _ in range(2)]
eng.run_until_done()
print(f"\npriority serving: hi ttft="
      f"{[f'{(r.t_first - r.t_submit) * 1e3:.0f}ms' for r in hi]}, "
      f"lo max_deferred={[r.max_deferred for r in lo]} (bound 4); "
      f"last decisions: "
      f"{[d['choice'] for d in list(eng.engine.decisions)[-3:]]}")

# ---- cross-request prefix cache + result cache ----------------------------
# requests sharing a system-prompt-style preamble: wave 1 prefills from
# scratch and snapshots slot rows at prefill tick boundaries into a radix
# tree; wave 2 admissions seed from the deepest cached prefix (a measured
# Engine.choose_prefix_admission decision), so prefill work shrinks to the
# unique suffix.  An exact repeat afterwards never touches a slot at all —
# the result cache answers it (greedy-only, params-versioned).
eng = ServeEngine(cfg, params, max_len=96, slots=2, prefill_chunk=16,
                  decode_chunk=4, prefix_cache=True)
preamble = rng.integers(1, cfg.vocab, (32,)).astype(np.int32)


def wave():
    rs = [eng.submit(np.concatenate(
        [preamble, rng.integers(1, cfg.vocab, (2,)).astype(np.int32)]),
        max_new=8) for _ in range(2)]
    eng.run_until_done()
    return rs


wave()                                            # warm + build the tree
w2 = wave()                                       # seeds from the snapshots
repeat = eng.submit(np.concatenate([w2[0].prompt]), max_new=8)
eng.run_until_done()                              # exact hit: no ticks run
st = eng.prefix.stats()
print(f"\nprefix cache: seeded={st['seeded']} admissions, "
      f"{st['tokens_avoided']} prefill tokens avoided, "
      f"snapshots={st['snapshots']}; exact repeat answered from the "
      f"result cache ({st['result_hits']} hit, "
      f"done={repeat.done.is_set()})")

# ---- device-placed pools: elastic scale with live slot migration ----------
# each slot pool commits its donated state to its own device group
# (simulate a multi-device host with
# XLA_FLAGS=--xla_force_host_platform_device_count=8); admission and tick
# arbitration price device-group contention, pools on disjoint groups
# co-dispatch their ticks in one scheduling round, and drain_pool migrates
# in-flight slots (jitted gather -> device_put -> batched row write) with
# bit-identical greedy continuations — zero requests dropped.
devs = jax.devices()
placements = {0: [devs[0]], 1: [devs[len(devs) // 2]]}
eng = ServeEngine(cfg, params, max_len=96, slots=2, pools=2,
                  prefill_chunk=8, decode_chunk=4, placements=placements)
reqs = [eng.submit(rng.integers(1, cfg.vocab, (6 + 2 * i,)).astype(np.int32),
                   max_new=10, pool=i % 2) for i in range(4)]
eng.run_until_done()                                  # warm (incl. both pools)
reqs = [eng.submit(rng.integers(1, cfg.vocab, (6 + 2 * i,)).astype(np.int32),
                   max_new=10, pool=i % 2) for i in range(4)]
for _ in range(3):
    eng.tick()                        # requests mid-flight on both pools...
eng.drain_pool(eng.pools[0].lid)      # ...scale pool 0 away, live
eng.run_until_done()
st = eng._inspect("status")["placement"]
print(f"\ndevice-placed pools: {len(devs)} host devices, drained pool 0 "
      f"mid-stream -> migrated={st['migrated_slots']} slots, pools left="
      f"{[p.lid for p in eng.pools]}, parallel group ticks="
      f"{st['parallel_group_ticks']}; all "
      f"{sum(len(r.tokens) >= r.max_new for r in reqs)}/4 requests finished "
      f"(outputs bit-identical to the unplaced engine)")

# ---- the Maestro region view the engine schedules with --------------------
wf = serve_tick_workflow(decode_slots=2, decode_chunk=4, prefill_tokens=64,
                         t_token=0.01)
print("\nserve-tick regions (Maestro):", [sorted(r) for r in schedule(wf)])
wf2 = Workflow()
for op in [Op("requests", "scan", 1.0, 1.0, 100),
           Op("prefill", "join", 5.0, 1.0),
           Op("decode", "op", 1.0, 16.0),
           Op("stream_out", "sink", 0.1, 1.0)]:
    wf2.add_op(op)
wf2.add_edge("requests", "prefill", blocking=True, port="build")
wf2.add_edge("prefill", "decode")
wf2.add_edge("decode", "stream_out")
print("serving pipeline regions:", [sorted(r) for r in schedule(wf2)])
