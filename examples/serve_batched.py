"""Batched serving demo: prefill + decode with KV/SSM caches across three
architecture families (attention / sliding-window / recurrent), plus the
Maestro view of serving: prefill is the blocking 'build' region, decode the
pipelined 'probe' region.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np
import jax

from repro.configs import get_arch
from repro.core.regions import Op, Workflow, regions, schedule
from repro.models import lm
from repro.runtime.serve import BatchedServer

rng = np.random.default_rng(0)

for arch in ("yi-34b-smoke", "gemma3-1b-smoke", "rwkv6-1.6b-smoke"):
    cfg = get_arch(arch)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, max_len=64)
    prompts = rng.integers(1, cfg.vocab, (4, 12)).astype(np.int32)
    t0 = time.time()
    out = srv.generate(prompts, max_new=12, temperature=0.8, seed=7)
    dt = time.time() - t0
    print(f"{arch:24s} batch=4 prefill=12 decode=12 "
          f"-> {out.shape} in {dt:.2f}s "
          f"({4 * 12 / dt:.1f} tok/s decode)")

# Maestro's region view of a serving pipeline: the prefill (build) must
# complete before decode (probe) streams — same machinery as Ch.4.
wf = Workflow()
for op in [Op("requests", "scan", 1.0, 1.0, 100),
           Op("prefill", "join", 5.0, 1.0),
           Op("decode", "op", 1.0, 16.0),
           Op("stream_out", "sink", 0.1, 1.0)]:
    wf.add_op(op)
wf.add_edge("requests", "prefill", blocking=True, port="build")
wf.add_edge("prefill", "decode")
wf.add_edge("decode", "stream_out")
print("\nserving regions (Maestro):",
      [sorted(r) for r in schedule(wf)])
