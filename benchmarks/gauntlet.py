"""The load gauntlet: every registered loadgen scenario, SLO-graded.

Each scenario family in ``repro.engine.loadgen.SCENARIOS`` runs against a
fresh tiny ServeEngine under the virtual-time drive harness; the measured
per-class TTFT percentiles / goodput / aging peaks are graded against the
scenario's SLOs (``repro.core.scheduler.grade_slo``) and emitted as one
``gauntlet/<scenario>`` row whose ``derived`` field carries the grade —
``slo=PASS`` or ``slo=FAIL(<criteria>)`` — so the CI gate can assert every
scenario passes by reading BENCH rows alone.

On top of the scenarios, ``gauntlet/autotune_recovery`` is the closed-loop
proof: an engine whose ``prefill_chunk`` is deliberately forced to a
pathological value (1 — one dispatch per prompt token) must, via the
AutoTuner's windowed wall-per-token measurement and the CostBook
bootstrap/re-explore discipline, move itself back to the fast arm while
serving a prefill-heavy stream.  The row reports the windows it took.

Per-scenario decision telemetry (the engine's ``choose_*`` deque, knob
state, and the drive summary) is exported as JSONL when
``GAUNTLET_TELEMETRY_DIR`` is set — the artifact the CI gauntlet job
uploads.

Smoke mode miniaturizes every scenario (fewer requests, shorter prompts)
so the whole gauntlet fits a CI job; thresholds are shared — they are
scale-generous tripwires for gross scheduling failures, not perf targets
(docs/STRESS_TESTS.md records measured margins at both scales).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np
import jax

sys.path.insert(0, "src")

from repro.configs import get_arch                             # noqa: E402
from repro.configs.base import PriorityClass                   # noqa: E402
from repro.core.scheduler import grade_slo                     # noqa: E402
from repro.engine import loadgen as lg                         # noqa: E402
from repro.engine.autotune import AutoTuner, Knob              # noqa: E402
from repro.engine.serve import ServeEngine                     # noqa: E402
from repro.models import lm                                    # noqa: E402

ARCH = "gemma3-1b-smoke"
MAX_LEN = 64
SEED = 1234

# scenarios that need a non-default engine shape
_STARVE_CLASSES = (PriorityClass("interactive", weight=4.0, max_defer=2),
                   PriorityClass("batch", weight=1.0, max_defer=6))
_ENGINE_KW = {
    "shared_preamble": {"prefix_cache": True},
    "chunk_thrash": {"spec_decode": True},
    "priority_starvation": {"slots": 2},
}

_params_cache = {}


def _params():
    if "p" not in _params_cache:
        cfg = get_arch(ARCH)
        _params_cache["cfg"] = cfg
        _params_cache["p"] = lm.init(cfg, jax.random.PRNGKey(0))
    return _params_cache["cfg"], _params_cache["p"]


def _mini(spec: lg.ScenarioSpec) -> lg.ScenarioSpec:
    """Smoke-scale a scenario: fewer requests, bounded lengths.  Keeps the
    arrival process and SLOs untouched — the grade thresholds are generous
    enough to hold at either scale."""
    clip = lambda ps, hi: tuple((k, min(v, hi) if k == "hi" else v)
                                for k, v in ps)
    return dataclasses.replace(
        spec, n=min(spec.n, 12),
        plen_params=clip(spec.plen_params, 12),
        max_new_params=clip(spec.max_new_params, 6))


def _engine_for(name: str) -> ServeEngine:
    cfg, params = _params()
    kw = dict(_ENGINE_KW.get(name, {}))
    if name == "priority_starvation":
        cfg = dataclasses.replace(
            cfg, serve=dataclasses.replace(cfg.serve,
                                           classes=_STARVE_CLASSES))
    return ServeEngine(cfg, params, max_len=MAX_LEN,
                       slots=kw.pop("slots", 3), prefill_chunk=8,
                       decode_chunk=2, seed=SEED, **kw)


def _telemetry(eng: ServeEngine, name: str, metrics, ok, detail) -> None:
    """One JSONL per scenario: every decision record the engine kept, then
    a trailing summary line with the metrics + grade (same schema
    ``scripts/dump_decisions.py`` emits, plus the gauntlet summary)."""
    out = os.environ.get("GAUNTLET_TELEMETRY_DIR")
    if not out:
        return
    os.makedirs(out, exist_ok=True)
    sys.path.insert(0, "scripts")
    from dump_decisions import decision_records
    info = eng._inspect("all")
    with open(os.path.join(out, f"{name}.jsonl"), "w") as f:
        for rec in decision_records(eng):
            f.write(json.dumps(rec) + "\n")
        f.write(json.dumps({
            "summary": name, "metrics": metrics, "slo_pass": ok,
            "slo_detail": detail, "knobs": info["knobs"],
            "autotune": info["autotune"]}) + "\n")


def run_scenario(name: str, smoke: bool = False):
    """Drive one registered scenario; returns (row, metrics, ok, detail)."""
    spec = lg.SCENARIOS[name]
    if smoke:
        spec = _mini(spec)
    eng = _engine_for(name)
    reqs = lg.generate(spec, SEED)
    res = lg.drive(eng, reqs, max_ticks=20_000, events=spec.event_list())
    metrics = lg.summarize(res)
    ok, detail = grade_slo(metrics, list(spec.slos))
    _telemetry(eng, name, metrics, ok, detail)
    us = res.wall_s * 1e6 / max(len(res.traces), 1)
    fails = ";".join(k for k, v in detail.items() if v.startswith("FAIL"))
    grade = "slo=PASS" if ok else f"slo=FAIL({fails})"
    by_cls = ";".join(
        f"{k}={metrics[k]:.1f}" for k in sorted(metrics)
        if "/" in k and k.split("/")[1] in ("p50_ttft", "p99_ttft"))
    derived = (f"{grade};n={int(metrics['n'])};"
               f"completed={int(metrics['completed'])};"
               f"dropped={int(metrics['dropped'])};"
               f"p50_ttft={metrics['p50_ttft']:.1f};"
               f"p99_ttft={metrics['p99_ttft']:.1f};"
               f"goodput={metrics['goodput']:.2f};"
               f"max_deferred={int(metrics['max_deferred'])};"
               f"ticks={int(metrics['ticks'])}"
               + (f";{by_cls}" if by_cls else ""))
    return (f"gauntlet/{name}", us, derived), metrics, ok, detail


def bench_autotune_recovery(smoke: bool = False):
    """Forced-bad-knob recovery: prefill_chunk wedged at 1 (one dispatch
    per prompt token) on a prefill-heavy stream; the AutoTuner must
    measure its way back to 16.  Recovery is judged on the CostBook state
    — the fast arm's windowed wall-per-token EMA beating the slow arm's —
    plus the live value, and the row reports the window count."""
    cfg, params = _params()
    from repro.engine import jobs as J
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, slots=2,
                      prefill_chunk=16, decode_chunk=2, seed=SEED)
    tuner = AutoTuner(eng, knobs=[Knob("prefill_chunk", (1, 16),
                                       key="prefill_chunk")],
                      window=4, warmup=1)
    eng.autotuner = tuner
    # wedge the knob: the tuner starts from — and must climb out of — the
    # pathological arm
    eng._apply_updates({"prefill_chunk": 1})
    tuner.current["prefill_chunk"] = 1
    n = 10 if smoke else 20
    rng = np.random.default_rng(SEED)
    t0 = time.perf_counter()
    reqs = [eng.submit(rng.integers(1, 97, size=int(rng.integers(24, 41)))
                       .astype(np.int32), max_new=int(rng.integers(2, 5)))
            for _ in range(n)]
    recovered_at = None
    ticks = 0
    while eng.queue or any(r is not None for r in eng.active):
        assert eng.tick(), "engine stopped"
        ticks += 1
        if recovered_at is None and tuner.current["prefill_chunk"] == 16:
            recovered_at = tuner.windows
        assert ticks < 50_000, "recovery bench did not drain"
    wall = time.perf_counter() - t0
    book = eng.engine.costs
    t_bad = book.estimate(J.knob_kind("prefill_chunk", 1))
    t_good = book.estimate(J.knob_kind("prefill_chunk", 16))
    # both arms measured and the book agrees the fast arm is fast: the
    # re-explore rotation may leave the LIVE value on either arm at drain,
    # so the durable verdict is the measured ordering + having moved
    recovered = (recovered_at is not None and t_bad is not None
                 and t_good is not None and t_good < t_bad)
    assert all(r.done.is_set() for r in reqs)
    return [(f"gauntlet/autotune_recovery", wall * 1e6 / max(ticks, 1),
             f"recovered={recovered};windows_to_recover={recovered_at};"
             f"windows={tuner.windows};moves={tuner.moves};"
             f"t_tok_bad={0 if t_bad is None else t_bad * 1e3:.3f}ms;"
             f"t_tok_good={0 if t_good is None else t_good * 1e3:.3f}ms;"
             f"ticks={ticks}")]


def benches(smoke: bool = False):
    """Per-bench registry for ``run.py --only`` / per-bench timeouts: one
    entry per scenario plus the recovery bench."""
    out = []
    for name in lg.SCENARIOS:
        out.append((name, lambda _n=name: [run_scenario(_n, smoke)[0]]))
    out.append(("autotune_recovery",
                lambda: bench_autotune_recovery(smoke)))
    return out


def run(smoke: bool = False):
    rows = []
    for _, fn in benches(smoke):
        rows.extend(fn())
    return rows
