"""Benchmarks on the real ML runtime: Amber pause latency (Fig 2.10/2.11),
breakpoint tau sweep (Fig 2.13), fault-tolerance overhead (Fig 2.16),
metric-collection overhead (Fig 3.25), live MoE Reshape (ours), and kernel
timings (ours)."""
from __future__ import annotations

import threading
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import messages as M
from repro.core.breakpoints import run_global_target_protocol
from repro.core.reshape_moe import MoEReshaper
from repro.core.skew import SkewParams
from repro.data.synthetic import TokenStream
from repro.optim.adamw import AdamWCfg
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.runtime.train import TrainHyper


def _loop(arch="olmoe-1b-7b", mb=2, ckpt_every=0, tmp="/tmp/repro_bench_ckpt",
          reshaper=None, class_alpha=0.0, seq=32, gb=8, step_path="auto"):
    cfg = get_arch(arch + "-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=seq, global_batch=gb,
                         seed=1, class_alpha=class_alpha)
    return TrainLoop(cfg, stream, TrainHyper(),
                     LoopConfig(microbatches=mb, ckpt_every=ckpt_every,
                                ckpt_dir=tmp, step_path=step_path),
                     reshaper=reshaper)


def bench_pause_latency():
    """Fig 2.10/2.11: wall-time from Pause send to Paused state, while a
    training job runs; median + p99 over repeated pauses.  Pinned to the
    granulated path — this figure measures the per-microbatch control
    point; under step_path=auto an async Pause lands at the next STEP
    boundary instead."""
    loop = _loop(step_path="granulated")
    loop.run(1)                                   # warm up jits
    lat = []

    def driver():
        for _ in range(8):
            time.sleep(0.15)
            t0 = time.monotonic()
            loop.controller.send(M.pause()).wait(30)
            lat.append(time.monotonic() - t0)
            loop.controller.send(M.resume()).wait(30)
        loop.controller.send(M.stop())

    th = threading.Thread(target=driver)
    th.start()
    loop.run(500)
    th.join()
    lat_ms = sorted(x * 1e3 for x in lat)
    med = lat_ms[len(lat_ms) // 2]
    return [("fig2.10_pause_latency", med * 1e3,
             f"median_ms={med:.1f};p99_ms={lat_ms[-1]:.1f};n={len(lat_ms)}")]


def bench_breakpoint_tau():
    """Fig 2.13: global-COUNT protocol — normal vs sync time vs tau."""
    rows = []
    rates = [10.0, 8.0, 6.0]
    for tau in (0.0, 0.05, 0.5, 2.0, 5.0):
        t0 = time.perf_counter()
        res = run_global_target_protocol(100_000, rates, tau)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig2.13_breakpoint_tau/{tau}", us,
                     f"total={res.total_time:.1f};sync={res.sync_time:.2f};"
                     f"normal={res.normal_time:.1f};rounds={res.rounds}"))
    return rows


def bench_fault_tolerance(tmp="/tmp/repro_bench_ft"):
    """Fig 2.16 + §2.7.8: checkpoint overhead + recovery time."""
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    loop = _loop(ckpt_every=0)
    t0 = time.perf_counter()
    loop.run(8)
    t_plain = time.perf_counter() - t0

    loop2 = _loop(ckpt_every=2, tmp=tmp)
    t0 = time.perf_counter()
    loop2.run(8)
    t_ckpt = time.perf_counter() - t0

    cfg = get_arch("olmoe-1b-7b-smoke")
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1)
    t0 = time.perf_counter()
    rec = TrainLoop.recover(cfg, stream, TrainHyper(),
                            LoopConfig(microbatches=2, ckpt_every=2,
                                       ckpt_dir=tmp))
    t_recover = time.perf_counter() - t0
    return [("fig2.16_ft_overhead", t_ckpt * 1e6,
             f"ckpt_overhead={(t_ckpt - t_plain) / t_plain:.1%};"
             f"recover_s={t_recover:.2f};recovered_step="
             f"{int(rec.state['step'])}")]


def bench_metric_overhead():
    """Fig 3.25: load-metric collection overhead (ours is fused -> ~0).

    Measurement protocol: warm-up passes, then *interleaved paired trials*
    with a median-of-repeats per arm.  A single timing window per arm
    reported up to -12.5% "overhead" — pure noise from allocator/frequency
    drift between the two windows; interleaving puts both arms through the
    same machine phases and the median rejects outlier trials, so the
    estimate lands inside the paper's 1-2% band instead of below zero."""
    cfg = get_arch("olmoe-1b-7b-smoke")
    from repro.models import lm, moe as moe_lib
    params = lm.init(cfg, jax.random.PRNGKey(0))
    plan = moe_lib.identity_plan(cfg, lm.n_moe_layers(cfg))
    batch = {"tokens": jnp.ones((8, 64), jnp.int32)}

    @jax.jit
    def fwd_with(params, b):
        logits, aux = lm.forward(params, b, cfg, plan=plan)
        return logits.sum(), aux["moe"]["expert_counts"]

    @jax.jit
    def fwd_without(params, b):
        logits, aux = lm.forward(params, b, cfg, plan=plan)
        return logits.sum()

    def timeit(f, n=15):
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(f(params, batch))
        return (time.perf_counter() - t0) / n * 1e6

    def median(xs):
        return sorted(xs)[len(xs) // 2]

    estimates, t_with = [], 0.0
    for trial in range(3):                   # macro-trials reject load phases
        for _ in range(5):                   # warm-up (compile + caches)
            jax.block_until_ready(fwd_with(params, batch))
            jax.block_until_ready(fwd_without(params, batch))
        t_w, t_wo = [], []
        for i in range(8):                   # alternated measurement windows
            if i % 2 == 0:
                t_w.append(timeit(fwd_with))
                t_wo.append(timeit(fwd_without))
            else:
                t_wo.append(timeit(fwd_without))
                t_w.append(timeit(fwd_with))
        t_with = median(t_w)
        estimates.append((t_with - median(t_wo)) / median(t_wo))
    ovh = median(estimates)
    spread = max(estimates) - min(estimates)
    return [("fig3.25_metric_overhead", t_with,
             f"overhead={ovh:.1%};trial_spread={spread:.1%} (paper: 1-2%)")]


def bench_moe_reshape():
    """Ours: live expert-skew mitigation during training — dropped tokens
    and load-balance before/after."""
    import dataclasses
    cfg = get_arch("olmoe-1b-7b-smoke")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    rows = []
    for name, rs in [
            ("baseline", None),
            ("reshape", MoEReshaper(cfg, 2, ep_ranks=2,
                                    params=SkewParams(eta=0.0, tau=0.15),
                                    phase1_steps=1))]:
        stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=8,
                             seed=5, class_alpha=2.0)
        loop = TrainLoop(cfg, stream, TrainHyper(),
                         LoopConfig(microbatches=1), reshaper=rs)
        t0 = time.perf_counter()
        hist = loop.run(12)
        us = (time.perf_counter() - t0) * 1e6 / 12
        drops = np.mean([h["dropped"].sum() for h in hist[-4:]])
        sc = hist[-1]["slot_counts"]
        per_rank = sc.reshape(sc.shape[0], 2, -1).sum(-1)
        lb = float(per_rank.min() / max(per_rank.max(), 1))
        rows.append((f"moe_reshape/{name}", us,
                     f"dropped={drops:.0f};rank_lb={lb:.2f};"
                     f"iters={getattr(rs, 'iterations', 0)}"))
    return rows


def bench_moe_dispatch():
    """Ours: the fused dispatch/combine family (kernels/moe_dispatch —
    one-hot-cumsum rank + single-writer bucketed scatter; jnp fused
    algorithm off-TPU, Pallas on TPU) vs the XLA argsort + searchsorted +
    scatter-add pipeline in models.moe.dispatch_combine.  Swept over
    token counts / expert counts, a skewed-routing case (capacity drops
    active), and one fwd+bwd row (the custom-VJP re-gather path)."""
    from repro.kernels.moe_dispatch import ops as dops
    from repro.models import moe as moe_lib
    rows = []
    rng = np.random.default_rng(0)
    d = 128

    def expert_fn(buf):
        return jax.nn.silu(buf)

    def median_time(f, *args, reps=10, trials=3):
        jax.block_until_ready(f(*args))
        ts = []
        for _ in range(trials):
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(f(*args))
            ts.append((time.perf_counter() - t0) / reps)
        return sorted(ts)[trials // 2]

    for (t, e, k, skew) in [(2048, 16, 2, False), (2048, 16, 2, True),
                            (4096, 64, 8, False)]:
        s = e + 2
        cap = max(4, int(t * k * 1.25 / e))
        x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
        slot_np = rng.integers(0, s, (t, k))
        if skew:
            slot_np[: t // 2, 0] = 0         # half the tokens hammer slot 0
        slot = jnp.asarray(slot_np, jnp.int32)
        w = jnp.asarray(rng.uniform(0.1, 1.0, (t, k)), jnp.float32)
        fns = {
            "xla": jax.jit(lambda x, sl, w, s=s, cap=cap:
                           moe_lib.dispatch_combine(
                               x, sl, w, expert_fn, s, cap)[0]),
            "fused": jax.jit(lambda x, sl, w, s=s, cap=cap:
                             dops.dispatch_combine(
                                 x, sl, w, expert_fn, s, cap)[0]),
        }
        tag = f"moe_dispatch/t{t}e{e}k{k}" + ("/skew" if skew else "")
        times = {name: median_time(f, x, slot, w) for name, f in fns.items()}
        for name, tm in times.items():
            rows.append((f"{tag}/{name}", tm * 1e6,
                         f"cap={cap};tok_s={t / tm:.0f}"))
        rows.append((f"{tag}/speedup", 0.0,
                     f"fused_over_xla={times['xla'] / times['fused']:.2f}x"))

    # fwd+bwd through the custom VJP (combine re-gather / dispatch
    # re-scatter) vs XLA autodiff of the sort pipeline
    t, e, k = 2048, 16, 2
    s, cap = e + 2, max(4, int(t * k * 1.25 / e))
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    slot = jnp.asarray(rng.integers(0, s, (t, k)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, (t, k)), jnp.float32)
    gfns = {
        "xla": jax.jit(jax.grad(lambda x, sl, w: moe_lib.dispatch_combine(
            x, sl, w, expert_fn, s, cap)[0].sum(), argnums=(0, 2))),
        "fused": jax.jit(jax.grad(lambda x, sl, w: dops.dispatch_combine(
            x, sl, w, expert_fn, s, cap)[0].sum(), argnums=(0, 2))),
    }
    times = {name: median_time(f, x, slot, w) for name, f in gfns.items()}
    for name, tm in times.items():
        rows.append((f"moe_dispatch/t{t}e{e}k{k}/grad/{name}", tm * 1e6,
                     f"tok_s={t / tm:.0f}"))
    rows.append((f"moe_dispatch/t{t}e{e}k{k}/grad/speedup", 0.0,
                 f"fused_over_xla={times['xla'] / times['fused']:.2f}x"))
    return rows


def bench_step_path():
    """Ours: fused fast path vs granulated control path, steps/s on
    olmoe-1b-7b-smoke.  The fused path scans all microbatches inside one jit
    (one dispatch + one D2H metrics fetch per step); granulated pays the
    Amber interactivity tax — dispatch, metric fetch, breakpoint check and
    controller poll — on every microbatch.  The gap grows with microbatch
    count (CPU numbers UNDERSTATE the accelerator win: XLA:CPU per-op
    latency dominates each microbatch's compute, while on TPU the
    per-microbatch host round-trips stall the device outright)."""
    import dataclasses
    rows = []
    for seq, gb, mb, steps in ((16, 16, 8, 6), (8, 32, 32, 4)):
        cfg = get_arch("olmoe-1b-7b-smoke")
        # fused step path + fused gating AND dispatch kernels: the whole
        # router/dispatch data plane off the argsort pipeline
        cfg_k = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, fused_gating=True,
                                         fused_dispatch=True))
        variants = {"granulated": (cfg, "granulated"),
                    "fused": (cfg, "fused"),
                    "fused_kernels": (cfg_k, "fused")}
        loops = {}
        for name, (c, path) in variants.items():
            stream = TokenStream(vocab=c.vocab, seq_len=seq,
                                 global_batch=gb, seed=1)
            loops[name] = TrainLoop(c, stream, TrainHyper(),
                                    LoopConfig(microbatches=mb,
                                               step_path=path))
            loops[name].run(2)                        # warm up jits
        # interleave paired trials so slow-machine phases hit both paths;
        # report the median per-path time and median per-trial ratio
        trials = {name: [] for name in variants}
        for _ in range(3):
            for name in variants:
                t0 = time.perf_counter()
                loops[name].run(steps)
                trials[name].append((time.perf_counter() - t0) / steps)
        times = {}
        for name in variants:
            t = sorted(trials[name])[1]
            times[name] = t
            rows.append((f"step_path/mb{mb}/{name}", t * 1e6,
                         f"steps_per_s={1.0 / t:.2f};seq={seq};gb={gb}"))
        ratios = sorted(g / f for g, f in zip(trials["granulated"],
                                              trials["fused"]))
        rows.append((f"step_path/mb{mb}/speedup", 0.0,
                     f"fused_over_granulated={ratios[1]:.2f}x"))
        rk = sorted(f / k for f, k in zip(trials["fused"],
                                          trials["fused_kernels"]))
        rows.append((f"step_path/mb{mb}/kernels_speedup", 0.0,
                     f"fused_kernels_over_fused={rk[1]:.2f}x"))
    return rows


def bench_reshaper_latency():
    """Ours: controller decision latency — vectorized MoEReshaper.step() vs
    the pre-vectorization loop implementation (LoopReshaper), across plan
    sizes and skew regimes at the paper-scale (L=16, E=64, R=4) point."""
    from repro.configs.base import ArchConfig, MoECfg
    from repro.core.reshape_moe import LoopReshaper

    def mk(cls, L, E, R, ranks):
        cfg = ArchConfig(name="bench", family="moe", num_layers=L,
                         d_model=64, n_heads=2, n_kv_heads=2, d_ff=256,
                         vocab=256, moe=MoECfg(num_experts=E, top_k=2,
                                               expert_d_ff=256,
                                               max_replicas=R))
        return cls(cfg, L, ep_ranks=ranks,
                   params=SkewParams(eta=0.0, tau=0.25), phase1_steps=1)

    def timed(rs, o, d, reps):
        for _ in range(5):
            rs.observe(o, d)
            rs.step()
        deltas = []
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(reps):
                rs.observe(o, d)
            t_obs = (time.perf_counter() - t0) / reps
            t0 = time.perf_counter()
            for _ in range(reps):
                rs.observe(o, d)
                rs.step()
            t_both = (time.perf_counter() - t0) / reps
            # paired within-trial delta; clamp so timing noise can never
            # emit a negative/zero latency into the perf artifact
            deltas.append(max(t_both - t_obs, 1e-9))
        return min(deltas)

    rng = np.random.default_rng(0)
    rows = []
    for (L, E, R, ranks) in [(16, 64, 4, 8), (32, 128, 4, 8)]:
        base = rng.uniform(80, 120, (L, E))
        skewed = base.copy()
        for l in range(max(1, L // 4)):
            skewed[l, l % E] += 3000
        d = rng.integers(0, 50, L)
        for scen, o in (("balanced", base), ("skewed", skewed)):
            t_vec = timed(mk(MoEReshaper, L, E, R, ranks), o, d, 100)
            t_loop = timed(mk(LoopReshaper, L, E, R, ranks), o, d, 20)
            rows.append((f"reshaper_latency/L{L}E{E}R{R}/{scen}",
                         t_vec * 1e6,
                         f"loop_us={t_loop * 1e6:.1f};"
                         f"speedup={t_loop / t_vec:.1f}x"))
    return rows


def bench_serve_throughput():
    """Ours: continuous-batching ServeEngine vs the old static BatchedServer
    loop at mixed prompt lengths.  The static path pays one decode dispatch
    per prompt token and per generated token, and must process each prompt
    length as its own lockstep batch; the engine runs chunked batched
    prefill + multi-token decode ticks over a continuously re-filled slot
    pool, with tick composition chosen by the Maestro min-FRT rule."""
    from repro.models import lm as lm_lib
    from repro.runtime.serve import BatchedServer

    cfg = get_arch("gemma3-1b-smoke")
    params = lm_lib.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # mixed traffic: prompt lengths AND response budgets vary per request
    lens = [4, 12, 20, 28] * 2
    news = [24, 8, 16, 4, 8, 24, 4, 16]
    prompts = [rng.integers(1, cfg.vocab, (l,)).astype(np.int32)
               for l in lens]
    groups = {}
    for p, n in zip(prompts, news):
        groups.setdefault(len(p), []).append((p, n))

    srv = BatchedServer(cfg, params, max_len=96, slots=4,
                        prefill_chunk=16, decode_chunk=8)
    n_tok = sum(news)                            # useful tokens per pass

    def run_static():
        # the old server batches in lockstep: one rectangular batch per
        # prompt length, decoded to the LONGEST response in the group
        for g in groups.values():
            srv.generate_static(np.stack([p for p, _ in g]),
                                max_new=max(n for _, n in g))

    def run_engine():
        eng = srv.engine()
        reqs = [eng.submit(p, max_new=n) for p, n in zip(prompts, news)]
        eng.run_until_done()
        assert all(r.done.is_set() for r in reqs)

    rows = []
    times = {}
    for name, fn in (("static", run_static), ("continuous", run_engine)):
        fn()                                     # warm the jits
        trials = []
        for _ in range(3):
            t0 = time.perf_counter()
            fn()
            trials.append(time.perf_counter() - t0)
        t = sorted(trials)[1]
        times[name] = t
        rows.append((f"serve_throughput/{name}", t * 1e6,
                     f"tok_s={n_tok / t:.1f};requests={len(prompts)};"
                     f"mixed_plens={sorted(set(lens))};"
                     f"max_new={min(news)}-{max(news)}"))
    rows.append(("serve_throughput/speedup", 0.0,
                 f"continuous_over_static="
                 f"{times['static'] / times['continuous']:.2f}x"))
    return rows


def bench_serve_spec():
    """Ours: the speculative proposer family — plain multi-token decode vs
    the n-gram suffix-table arm vs the DRAFT-MODEL arm (a tiny independent
    draft distilled from the target's own greedy streams, proposing inside
    the same chunk-scan dispatch) — at repetitive, random and mixed
    workloads.  Arms are forced on for their rows so the A/B is clean;
    greedy outputs are asserted bit-identical across all three.

    Acceptance is the whole story: the n-gram table only lands on streams
    that loop (repetitive), while the distilled draft imitates the target's
    argmax on ANY of its traffic — random text included — so the draft arm
    is the one that finally wins off the repetitive regime.  Chain length
    tracks proposer quality: the draft arm runs spec_len=8 (high acceptance
    amortizes the verify scan over longer commits), the n-gram arm keeps
    the default 4 (longer chains just reject more).  The final row drops
    the forcing and reports which arm the engine's measured per-arm EMAs
    (Engine._choose_decode_arm) actually converge to."""
    import dataclasses as dc
    from collections import Counter

    from repro.engine.draft import distill_draft, small_draft_cfg
    from repro.engine.serve import ServeEngine
    from repro.models import lm as lm_lib

    cfg = get_arch("gemma3-1b-smoke")
    cfg8 = dc.replace(cfg, serve=dc.replace(cfg.serve, spec_len=8))
    params = lm_lib.init(cfg, jax.random.PRNGKey(0))
    max_new = 64
    # "repetitive" is a prompt whose greedy continuation locks into a tight
    # loop (measured: ~85% periodic within 80 tokens on this init) — the
    # regime prompt-lookup/n-gram speculation exists for; "random" prompts
    # mostly keep the stream switching attractors, so n-gram drafts rarely
    # land there; "mixed" is the production blend
    rep = np.random.default_rng(1).integers(1, cfg.vocab, (8,)).astype(
        np.int32)
    rng = np.random.default_rng(0)
    rnd = [rng.integers(1, cfg.vocab, (8,)).astype(np.int32)
           for _ in range(6)]
    workloads = {
        "repetitive": [rep.copy() for _ in range(6)],
        "random": rnd,
        "mixed": [rep.copy() for _ in range(3)] + rnd[:3],
    }
    # distill the tiny draft on the bench's own traffic (the production
    # loop: keep serving while a draft distills, republish it hot via
    # update(draft_params=...)).  ~7% of the target's per-step cost.
    dcfg = small_draft_cfg(cfg)
    t0 = time.perf_counter()
    dparams = distill_draft(cfg, params, dcfg, [rep] + rnd, max_new=64,
                            steps=400)
    distill_s = time.perf_counter() - t0

    ARMS = {"plain": (cfg, {}), "ngram": (cfg, {"spec_decode": True}),
            "draft": (cfg8, {"spec_decode": True,
                             "draft_cfg": dc.replace(dcfg,
                                                     serve=cfg8.serve),
                             "draft_params": dparams})}

    def run_once(prompts, arm):
        acfg, kw = ARMS[arm]
        eng = ServeEngine(acfg, params, max_len=160, slots=4,
                          prefill_chunk=16, decode_chunk=4,
                          compact_decode=False, **kw)
        if arm != "plain":
            orig = eng.engine.choose_serve_tick

            def force(*a, **k):
                m = orig(*a, **k)
                return f"spec:{arm}" if m != "prefill" \
                    and k.get("spec_len", 0) > 1 else m

            eng.engine.choose_serve_tick = force
        reqs = [eng.submit(p, max_new=max_new) for p in prompts]
        eng.run_until_done()
        return eng, [r.output() for r in reqs]

    rows = []
    for wname, prompts in workloads.items():
        outs, times, n_tok = {}, {}, max_new * len(prompts)
        for arm in ("plain", "ngram", "draft"):
            run_once(prompts, arm)                   # warm the tick jits
            trials, eng, out = [], None, None
            for _ in range(3):
                t0 = time.perf_counter()
                eng, out = run_once(prompts, arm)
                trials.append(time.perf_counter() - t0)
            t = sorted(trials)[1]
            times[arm], outs[arm] = t, out
            extra = ""
            if arm != "plain":
                a = eng.spec_accepted / max(eng.spec_proposed, 1)
                extra = (f";accept={a:.2f};spec_ticks={eng.spec_ticks};"
                         f"drafts={eng.spec_proposed}")
            rows.append((f"serve_spec/{wname}/{arm}", t * 1e6,
                         f"tok_s={n_tok / t:.1f}{extra}"))
        for arm in ("ngram", "draft"):               # greedy bit-identity
            for a, b in zip(outs["plain"], outs[arm]):
                np.testing.assert_array_equal(a, b)
        rows.append((f"serve_spec/{wname}/speedup", 0.0,
                     f"ngram_over_plain="
                     f"{times['plain'] / times['ngram']:.2f}x;"
                     f"draft_over_plain="
                     f"{times['plain'] / times['draft']:.2f}x"))
    # un-forced: one engine serving the repetitive workload repeatedly, so
    # the per-arm acceptance/runtime EMAs accumulate and the measured
    # decision converges; report what the engine actually picked
    eng = ServeEngine(cfg8, params, max_len=160, slots=4, prefill_chunk=16,
                      decode_chunk=4, compact_decode=False,
                      spec_decode=True,
                      draft_cfg=dc.replace(dcfg, serve=cfg8.serve),
                      draft_params=dparams)
    for _ in range(6):
        for p in workloads["repetitive"]:
            eng.submit(p, max_new=max_new)
        eng.run_until_done()
    picks = Counter(d["choice"] for d in eng.engine.decisions
                    if d["decision"] == "serve_decode_arm"
                    and d.get("why") is None)
    top = picks.most_common(1)[0][0] if picks else "none"
    rows.append(("serve_spec/decision", 0.0,
                 f"top={top};measured_picks=" +
                 ",".join(f"{k}:{v}" for k, v in sorted(picks.items())) +
                 f";distill_s={distill_s:.1f}"))
    return rows


def bench_serve_priority():
    """Ours: priority-aware multi-pool serving.  A batch ("lo") workload of
    long prompts is mid-flight across TWO slot pools when a burst of
    interactive ("hi", weight 8:1) requests arrives; the A/B is the same
    engine shape with the default single-class table (the weighted-FRT
    arbitration runs in both — the class table is the only difference).
    Reported: p50 time-to-first-token and completion for the hi burst, lo
    throughput, and the peak aging deferral against the class bound — the
    priority win is only real if no lo request ever sits out more than
    ``max_defer`` scheduled ticks."""
    import dataclasses as dc

    from repro.configs.base import PriorityClass
    from repro.engine.serve import ServeEngine
    from repro.models import lm as lm_lib

    cfg0 = get_arch("gemma3-1b-smoke")
    classes = (PriorityClass("hi", 8.0, 8), PriorityClass("lo", 1.0, 8))
    cfg_prio = dc.replace(cfg0, serve=dc.replace(cfg0.serve,
                                                 classes=classes))
    params = lm_lib.init(cfg0, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lo_prompts = [rng.integers(1, cfg0.vocab, (24,)).astype(np.int32)
                  for _ in range(4)]
    hi_prompts = [rng.integers(1, cfg0.vocab, (4,)).astype(np.int32)
                  for _ in range(4)]
    lo_new, hi_new = 32, 16

    def run_once(prioritized):
        eng = ServeEngine(cfg_prio if prioritized else cfg0, params,
                          max_len=160, slots=3, pools=2,
                          prefill_chunk=8, decode_chunk=4)
        prio = (lambda c: c) if prioritized else (lambda c: None)
        lo = [eng.submit(p, max_new=lo_new, priority=prio("lo"))
              for p in lo_prompts]
        for _ in range(2):
            eng.tick()                       # the batch load is mid-flight
        hi = [eng.submit(p, max_new=hi_new, priority=prio("hi"))
              for p in hi_prompts]
        eng.run_until_done()
        return eng, hi, lo

    def p50(xs):
        return sorted(xs)[len(xs) // 2]

    rows, stats = [], {}
    for arm, prioritized in (("baseline", False), ("classes", True)):
        run_once(prioritized)                # warm this arm's tick jits
        trials = []
        for _ in range(3):
            t0 = time.perf_counter()
            eng, hi, lo = run_once(prioritized)
            wall = time.perf_counter() - t0
            trials.append((wall, eng, hi, lo))
        wall, eng, hi, lo = sorted(trials, key=lambda x: x[0])[1]
        ttft = p50([r.t_first - r.t_submit for r in hi])
        done = p50([r.t_done - r.t_submit for r in hi])
        worst_defer = max(r.max_deferred for r in hi + lo)
        lo_tok_s = lo_new * len(lo) / wall
        stats[arm] = (ttft, done, worst_defer, lo_tok_s)
        rows.append((f"serve_priority/{arm}/hi", ttft * 1e6,
                     f"p50_ttft_ms={ttft * 1e3:.1f};"
                     f"p50_done_ms={done * 1e3:.1f};n={len(hi)}"))
        rows.append((f"serve_priority/{arm}/lo", wall * 1e6,
                     f"tok_s={lo_tok_s:.1f};max_deferred={worst_defer};"
                     f"defer_bound={classes[1].max_defer}"))
    base, cls = stats["baseline"], stats["classes"]
    assert cls[2] <= classes[1].max_defer, \
        f"aging bound violated: {cls[2]} > {classes[1].max_defer}"
    rows.append(("serve_priority/speedup", 0.0,
                 f"hi_ttft_base_over_classes={base[0] / cls[0]:.2f}x;"
                 f"hi_done_base_over_classes={base[1] / cls[1]:.2f}x;"
                 f"lo_tok_s_ratio={cls[3] / base[3]:.2f}"))
    return rows


def bench_prefix_cache():
    """Ours: cross-request prefix cache + result cache.  Two workloads, each
    an A/B of the same ServeEngine with the cache off vs on:

    * **shared** — every request extends one 48-token preamble (the
      system-prompt / few-shot regime the cache exists for).  Wave 1 warms
      the radix tree (prefill-boundary snapshots at 16/32/48); wave 2 is
      measured: admissions seed from the depth-48 snapshot, so prefill work
      drops from 50 tokens to the 2-token unique suffix and TTFT falls
      accordingly.  Decode is untouched — the engine decision only replaces
      prefill — so tokens/s through decode must hold.
    * **disjoint** — fresh random prompts, nothing shareable: bounds the
      overhead the cache machinery (radix lookups, boundary snapshots,
      result-cache bookkeeping) adds when it never pays off.

    Outputs are asserted bit-identical between the arms — the cache is a
    pure perf layer on greedy traffic."""
    from repro.engine.serve import ServeEngine
    from repro.models import lm as lm_lib

    cfg = get_arch("gemma3-1b-smoke")
    params = lm_lib.init(cfg, jax.random.PRNGKey(0))
    max_new = 8
    shared = np.random.default_rng(7).integers(
        1, cfg.vocab, (48,)).astype(np.int32)

    def shared_waves():
        r = np.random.default_rng(0)
        return [[np.concatenate([shared,
                                 r.integers(1, cfg.vocab, (2,)).astype(
                                     np.int32)]) for _ in range(8)]
                for _ in range(2)]

    def disjoint_waves():
        # 3x the requests of the shared workload: the effect being bounded
        # here (lookup/bookkeeping overhead) is a few percent, so the
        # measurement needs to be long enough that timer noise isn't it
        r = np.random.default_rng(1)
        return [[r.integers(1, cfg.vocab, (10,)).astype(np.int32)
                 for _ in range(24)] for _ in range(2)]

    def run(waves, prefix):
        """Run the waves on a fresh engine; returns per-wave (wall, p50
        TTFT) plus every output and the engine (for the cache counters)."""
        eng = ServeEngine(cfg, params, max_len=96, slots=4,
                          prefill_chunk=16, decode_chunk=4,
                          prefix_cache=prefix)
        stats, outs = [], []
        for prompts in waves:
            t0 = time.perf_counter()
            reqs = [eng.submit(p, max_new=max_new) for p in prompts]
            eng.run_until_done()
            wall = time.perf_counter() - t0
            ttft = float(np.median([r.t_first - r.t_submit for r in reqs]))
            stats.append((wall, ttft))
            outs.extend(r.output() for r in reqs)
        return eng, stats, outs

    rows = []
    for wname, mkwaves in (("shared", shared_waves),
                           ("disjoint", disjoint_waves)):
        run(mkwaves(), True)                       # warm every jit involved
        run(mkwaves(), False)
        # interleave the arms round by round so machine drift lands on both
        # equally, then take medians of the *paired* per-round ratios — at
        # the ~25ms disjoint scale an unpaired A-then-B split reads drift
        # as overhead
        trials = {False: [], True: []}
        for _ in range(5):
            for arm in (False, True):
                trials[arm].append(run(mkwaves(), arm))
        res = {}
        for arm in (False, True):
            # wave 2 is the steady state: the tree is warm, every admission
            # can seed; outputs are deterministic, so any trial for identity
            eng, _, outs = trials[arm][-1]
            wall2 = float(np.median([t[1][1][0] for t in trials[arm]]))
            ttft2 = float(np.median([t[1][1][1] for t in trials[arm]]))
            res[arm] = (wall2, ttft2, outs)
            extra = ""
            if arm:
                st = eng.prefix.stats()
                extra = (f";seeded={st['seeded']};"
                         f"tokens_avoided={st['tokens_avoided']};"
                         f"snapshots={st['snapshots']}")
            n_tok = max_new * (8 if wname == "shared" else 24)
            rows.append((f"prefix_cache/{wname}/{'on' if arm else 'off'}",
                         wall2 * 1e6,
                         f"ttft_p50_us={ttft2 * 1e6:.0f};"
                         f"tok_s={n_tok / wall2:.1f}{extra}"))
        for a, b in zip(res[False][2], res[True][2]):
            np.testing.assert_array_equal(a, b)    # greedy bit-identity
        pair = lambda j: float(np.median(         # noqa: E731
            [f[1][1][j] / n[1][1][j]
             for f, n in zip(trials[False], trials[True])]))
        if wname == "shared":
            rows.append(("prefix_cache/shared/speedup", 0.0,
                         f"ttft_off_over_on={pair(1):.2f}x;"
                         f"wall_off_over_on={pair(0):.2f}x"))
        else:
            rows.append(("prefix_cache/disjoint/overhead", 0.0,
                         f"wall_on_over_off={1.0 / pair(0):.2f}x"))
    return rows


def bench_pool_placement():
    """Ours: device-placed slot pools.  Two sub-benches:

    * **placed vs default** — the same 2-pool workload with pools committed
      to disjoint device halves vs everything on the default device.  On a
      multi-device multi-core host the placed arm's scheduling rounds
      co-dispatch decode ticks for both pools (async PJRT dispatch overlaps
      them), so aggregate tokens/s should rise toward 2x.  The ratio row is
      ALWAYS emitted — with ``devices=``/``cores=`` fields so the perf
      trajectory is interpretable — but the >=1.4x gate only arms where
      overlap is physically possible (>=2 devices AND >=2 cores: a forced
      8-device single-core host runs every dispatch on one thread, ratio
      ~1.0 by construction).
    * **drain under load** — a saturated placed run with a mid-stream
      ``drain_pool``: always asserted, zero dropped requests and greedy
      outputs bit-identical to the undrained placed run (migration may only
      ever RELOCATE work).
    """
    import os

    from repro.engine.serve import ServeEngine
    from repro.models import lm as lm_lib

    cfg = get_arch("gemma3-1b-smoke")
    params = lm_lib.init(cfg, jax.random.PRNGKey(0))
    devs = jax.devices()
    # one device per pool: disjoint single-device meshes are the
    # parallelism-bearing configuration (no intra-pool SPMD partitioning
    # overhead — at smoke-model sizes a multi-device slot-dim split costs
    # more in per-device dispatch than it saves in compute)
    placements = {0: [devs[0]], 1: [devs[len(devs) // 2]]}
    max_new = 12
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, (int(n),)).astype(np.int32)
               for n in rng.integers(4, 12, size=8)]

    def run_once(plc, drain_at=None, pins=None):
        eng = ServeEngine(cfg, params, max_len=96, slots=4, pools=2,
                          prefill_chunk=8, decode_chunk=4, placements=plc)
        t0 = time.perf_counter()
        reqs = [eng.submit(p, max_new=max_new,
                           pool=None if pins is None else pins[i])
                for i, p in enumerate(prompts)]
        t = 0
        while eng.queue or any(r is not None for r in eng.active):
            if t == drain_at and len(eng.pools) > 1:
                eng.drain_pool(eng.pools[0].lid)
            assert eng.tick() and t < 2000
            t += 1
        wall = time.perf_counter() - t0
        return eng, wall, [r.output() for r in reqs]

    run_once(None)                                 # warm both arms' jits
    run_once(placements)
    trials = {"default": [], "placed": []}
    for _ in range(3):                             # interleaved pairing
        trials["default"].append(run_once(None))
        trials["placed"].append(run_once(placements))
    n_tok = max_new * len(prompts)
    walls = {arm: float(np.median([t[1] for t in ts]))
             for arm, ts in trials.items()}
    for a, b in zip(trials["default"][-1][2], trials["placed"][-1][2]):
        np.testing.assert_array_equal(a, b)        # placement: perf only
    rows = []
    for arm in ("default", "placed"):
        eng = trials[arm][-1][0]
        extra = ""
        if arm == "placed":
            pl = eng._inspect("status")["placement"]
            extra = (f";pools_placed={pl['placed_pools']};"
                     f"parallel_group_ticks={pl['parallel_group_ticks']}")
        rows.append((f"pool_placement/{arm}", walls[arm] * 1e6,
                     f"tok_s={n_tok / walls[arm]:.1f}{extra}"))
    ratio = float(np.median([d[1] / p[1] for d, p in
                             zip(trials["default"], trials["placed"])]))
    cores = os.cpu_count() or 1
    rows.append(("pool_placement/speedup", 0.0,
                 f"placed_over_default={ratio:.2f}x;"
                 f"devices={jax.device_count()};cores={cores}"))
    if jax.device_count() >= 2 and cores >= 2:
        assert ratio >= 1.4, \
            f"placed pools under 1.4x on a parallel host: {ratio:.2f}x"

    # drain under load: mid-stream scale-in, zero drops, identical outputs.
    # Admissions pinned 6-on-pool-0 / 2-on-pool-1 so the drained pool holds
    # live slots AND the survivor has free capacity — the migration path
    # must actually carry state across, not just wait the pool out.
    pins = [0] * 6 + [1] * 2
    _, _, ref_outs = run_once(placements, pins=pins)
    run_once(placements, drain_at=2, pins=pins)    # warm the migrate jits
    eng_d, wall_d, outs_d = run_once(placements, drain_at=2, pins=pins)
    for a, b in zip(ref_outs, outs_d):
        np.testing.assert_array_equal(a, b)
    assert not eng_d.queue and all(len(o) == max_new for o in outs_d)
    assert len(eng_d.pools) == 1, "drained pool still present"
    assert eng_d.migrated_slots >= 1, "drain never migrated a slot"
    rows.append(("pool_placement/drain", wall_d * 1e6,
                 f"migrated={eng_d.migrated_slots};dropped=0;"
                 f"wall_over_placed={wall_d / walls['placed']:.2f}x"))
    return rows


def bench_weight_publish(tmp="/tmp/repro_bench_pub"):
    """Async checkpointing + live weight publishing (ROADMAP item 3).

    (a) Save stall: the wall time ``TrainLoop.save`` holds up the training
    thread, blocking baseline (snapshot + inline persist) vs the two-region
    async path (snapshot only; persist overlapped on the worker).  The
    async stall must not exceed the blocking one — the persist region has
    left the critical path.  (b) Serve-side publish: p99 tick wall of a
    request stream that hot-swaps weights mid-stream every few ticks
    (value-identical params + version bump: the full invalidation work —
    prefix flush, placed-params re-commit, result-cache re-key — without
    changing outputs) vs the same stream without publishes, with zero
    dropped requests."""
    import shutil
    from repro.engine.serve import ServeEngine
    from repro.models import lm as lm_lib

    shutil.rmtree(tmp, ignore_errors=True)
    rows = []
    # --- (a) checkpoint save stall, blocking vs async ---------------------
    stalls = {}
    for mode in ("blocking", "async"):
        # ckpt_every is huge so the loop never auto-saves: the bench drives
        # save() by hand to time the stall in isolation
        loop = _loop(ckpt_every=10**9, tmp=f"{tmp}/{mode}")
        loop.lc.ckpt_async = mode == "async"
        loop.run(1)                               # warm the step jits
        ts = []
        for i in range(8):
            t0 = time.perf_counter()
            loop.save(i + 1)
            ts.append(time.perf_counter() - t0)
            loop.run(1)                           # the overlapped next step
        loop.ckpt.wait()
        stalls[mode] = float(np.median(ts))
        rows.append((f"weight_publish/save_stall_{mode}",
                     stalls[mode] * 1e6,
                     f"median_ms={stalls[mode] * 1e3:.2f};saves={len(ts)}"))
    ratio = stalls["blocking"] / max(stalls["async"], 1e-9)
    overlap = 1.0 - stalls["async"] / max(stalls["blocking"], 1e-12)
    rows.append(("weight_publish/save_speedup", 0.0,
                 f"stall_block_over_async={ratio:.2f}x;"
                 f"overlap={overlap:.2f}"))
    assert ratio >= 1.0, \
        f"async save stalled longer than blocking: {ratio:.2f}x"

    # --- (b) serve-side publish stall + zero drops ------------------------
    cfg = get_arch("gemma3-1b-smoke")
    params = lm_lib.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    shared = rng.integers(1, cfg.vocab, (8,)).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, cfg.vocab,
                                            (int(l),)).astype(np.int32)])
               for l in rng.integers(2, 10, 12)]

    def run_stream(publish_every):
        eng = ServeEngine(cfg, params, max_len=64, slots=4, prefill_chunk=8,
                          decode_chunk=4, prefix_cache=True)
        reqs = [eng.submit(p, max_new=8) for p in prompts]
        walls, t, publishes = [], 0, 0
        while eng.queue or any(r is not None for r in eng.active):
            if publish_every and t and t % publish_every == 0:
                eng.update(params=jax.tree.map(lambda x: x, eng.params),
                           params_version=eng.params_version + 1)
                publishes += 1
            t0 = time.perf_counter()
            assert eng.tick() and t < 2000
            walls.append(time.perf_counter() - t0)
            t += 1
        dropped = sum(not r.done.is_set() for r in reqs)
        return walls, publishes, dropped

    run_stream(0)                                  # warm the tick jits
    base, _, drop_b = run_stream(0)
    pub, n_pub, drop_p = run_stream(3)
    assert drop_b == 0 and drop_p == 0 and n_pub >= 2
    p99 = lambda w: float(np.percentile(w, 99))
    rows.append(("weight_publish/serve_base", p99(base) * 1e6,
                 f"p99_ms={p99(base) * 1e3:.2f};ticks={len(base)}"))
    rows.append(("weight_publish/serve_publish", p99(pub) * 1e6,
                 f"p99_ms={p99(pub) * 1e3:.2f};ticks={len(pub)};"
                 f"publishes={n_pub};dropped=0;"
                 f"p99_pub_over_base={p99(pub) / max(p99(base), 1e-12):.2f}x"))
    return rows


def bench_kernels():
    """Kernel microbenchmarks (jnp chunked path timings on CPU + numerics
    vs oracle; the Pallas kernels are TPU-target, validated in tests)."""
    rows = []
    rng = np.random.default_rng(0)

    # flash attention chunked
    from repro.models.attention import chunked_attention
    q = jnp.asarray(rng.standard_normal((2, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 512, 2, 64)), jnp.float32)
    f = jax.jit(lambda q, k: chunked_attention(q, k, k, causal=True))
    f(q, k).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        f(q, k).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    flops = 4 * 512 * 512 / 2 * 8 * 64 * 2
    rows.append(("kernel/flash_attention_b2s512", us,
                 f"gflops_s={flops / us / 1e3:.1f}"))

    # rwkv6 chunked
    from repro.kernels.rwkv6_scan.ops import rwkv6_chunked
    r = jnp.asarray(rng.standard_normal((2, 8, 512, 64)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.9, 0.999, (2, 8, 512, 64)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((8, 64)) * 0.1, jnp.float32)
    g = jax.jit(lambda r, w, u: rwkv6_chunked(r, r, r, w, u, chunk=64)[0])
    g(r, w, u).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        g(r, w, u).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append(("kernel/rwkv6_b2s512", us, "chunk=64"))

    # mamba2 chunked
    from repro.kernels.mamba2_ssd.ops import mamba2_chunked
    x = jnp.asarray(rng.standard_normal((2, 8, 512, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (2, 8, 512)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, 8), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((2, 512, 16)), jnp.float32)
    dsk = jnp.asarray(rng.standard_normal(8) * 0.1, jnp.float32)
    h = jax.jit(lambda x, dt, bm: mamba2_chunked(x, dt, a, bm, bm, dsk,
                                                 chunk=64)[0])
    h(x, dt, bm).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        h(x, dt, bm).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append(("kernel/mamba2_b2s512", us, "chunk=64"))

    # fused gating (pallas interpret) vs ref
    from repro.kernels.moe_gating.ref import gating_ref
    logits = jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32)
    gr = jax.jit(lambda l: gating_ref(l, 8))
    jax.block_until_ready(gr(logits))
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(gr(logits))
    us = (time.perf_counter() - t0) / 10 * 1e6
    rows.append(("kernel/gating_t4096e64", us, "top8+histogram fused"))
    return rows


def benches(smoke: bool = False):
    """Per-bench registry for ``run.py --only`` and per-bench timeouts.
    Order matters: timing-sensitive comparisons (step_path, serve,
    reshaper) run FIRST — the long-running Amber benches leave the
    allocator/caches warm in ways that skew both sides of a later A/B
    comparison.  smoke=True (CI) keeps just the A/B comparisons that gate
    PRs.  Each entry gc-collects after itself so one bench's loops/params
    are freed before the next one times anything."""
    fns = (bench_step_path, bench_serve_throughput, bench_serve_spec,
           bench_serve_priority, bench_prefix_cache, bench_pool_placement,
           bench_weight_publish, bench_moe_dispatch, bench_reshaper_latency)
    if not smoke:
        # metric_overhead is the most delicate A/B of all (a 1-2% effect on
        # a ~10 ms call): it must run before the long Amber benches leave
        # the allocator in a state that skews one side of the pair
        fns += (bench_metric_overhead, bench_pause_latency,
                bench_breakpoint_tau, bench_fault_tolerance,
                bench_moe_reshape, bench_kernels)

    def wrap(fn):
        def thunk():
            import gc
            try:
                return fn()
            finally:
                gc.collect()
        return thunk

    return [(fn.__name__.removeprefix("bench_"), wrap(fn)) for fn in fns]


def run(smoke: bool = False):
    rows = []
    for _, fn in benches(smoke):
        rows.extend(fn())
    return rows
