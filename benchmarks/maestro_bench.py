"""Maestro benchmarks: first-response time and materialized size across
choices and input sizes (paper Figs 4.21-4.24)."""
from __future__ import annotations

import time

from repro.core.materialization import enumerate_choices
from repro.core.regions import Op, Workflow
from repro.core.scheduler import (CostModel, choose, first_response_time,
                                  materialized_bytes)


def w1(card: float) -> Workflow:
    """Fig 4.20 W1-like: scan -> replicate -> {filter->join.probe,
    join.build} -> ml -> sink."""
    wf = Workflow()
    for op in [Op("scan", "scan", 1.0, 1.0, card),
               Op("rep", "replicate", 0.1, 2.0),
               Op("filter", "filter", 1.0, 0.4),
               Op("join", "join", 2.0, 0.5),
               Op("ml", "ml", 6.0, 1.0),
               Op("sink", "sink", 0.1, 1.0)]:
        wf.add_op(op)
    wf.add_edge("scan", "rep")
    wf.add_edge("rep", "filter")
    wf.add_edge("rep", "join", blocking=True, port="build")
    wf.add_edge("filter", "join", port="probe")
    wf.add_edge("join", "ml").add_edge("ml", "sink")
    return wf


def w2(card: float) -> Workflow:
    """Fig 4.20 W2-like: two joins fed by one scan through replicates."""
    wf = Workflow()
    for op in [Op("scan", "scan", 1.0, 1.0, card),
               Op("d1", "replicate", 0.1, 2.0),
               Op("f1", "filter", 1.0, 0.5),
               Op("j1", "join", 2.0, 0.6),
               Op("d2", "replicate", 0.1, 2.0),
               Op("m1", "ml", 5.0, 1.0),
               Op("j2", "join", 2.0, 0.5),
               Op("sink", "sink", 0.1, 1.0)]:
        wf.add_op(op)
    wf.add_edge("scan", "d1")
    wf.add_edge("d1", "f1")
    wf.add_edge("d1", "j1", blocking=True, port="build")
    wf.add_edge("f1", "j1", port="probe")
    wf.add_edge("j1", "d2")
    wf.add_edge("d2", "m1")
    wf.add_edge("d2", "j2", blocking=True, port="build")
    wf.add_edge("m1", "j2", port="probe")
    wf.add_edge("j2", "sink")
    return wf


def run():
    rows = []
    cm = CostModel(parallelism=4.0)
    for name, mk in (("W1", w1), ("W2", w2)):
        for card in (1e4, 1e5, 1e6):
            wf = mk(card)
            t0 = time.perf_counter()
            best, info = choose(wf, cm)
            us = (time.perf_counter() - t0) * 1e6
            frts = [f for f, b, c in info["all"]]
            rows.append((f"fig4.21_frt/{name}_card{card:.0e}", us,
                         f"best_frt={info['frt']:.0f};"
                         f"worst_frt={max(frts):.0f};"
                         f"choices={len(frts)};"
                         f"speedup={max(frts) / max(info['frt'], 1e-9):.2f}x"))
            sizes = [b for f, b, c in info["all"]]
            rows.append((f"fig4.23_matsize/{name}_card{card:.0e}", us,
                         f"chosen_bytes={info['bytes']:.2e};"
                         f"min_bytes={min(sizes):.2e};"
                         f"max_bytes={max(sizes):.2e}"))
    return rows
