# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV; ``--json PATH`` additionally writes the rows as a perf-trajectory
# artifact (e.g. BENCH_runtime.json) for CI comparison across PRs.
# Sub-suites: paper_sim (Reshape Ch.3 figures on the Tier-A simulator),
# runtime_bench (Amber Ch.2 + live-MoE on the real JAX runtime),
# maestro_bench (Ch.4 FRT/materialization).
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all", "sim", "runtime", "maestro"])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON perf artifact")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: only the fast A/B comparison benches "
                         "of the runtime suite")
    args = ap.parse_args()

    sys.path.insert(0, "src")
    suites = []
    if args.suite in ("all", "sim") and not args.smoke:
        from benchmarks import paper_sim
        suites.append(("sim", paper_sim.run))
    if args.suite in ("all", "runtime"):
        from benchmarks import runtime_bench
        suites.append(("runtime",
                       (lambda: runtime_bench.run(smoke=True))
                       if args.smoke else runtime_bench.run))
    if args.suite in ("all", "maestro") and not args.smoke:
        from benchmarks import maestro_bench
        suites.append(("maestro", maestro_bench.run))

    print("name,us_per_call,derived")
    failures = 0
    results = []
    for sname, fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
                results.append({"suite": sname, "name": name,
                                "us_per_call": round(us, 1),
                                "derived": derived})
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{sname}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            results.append({"suite": sname, "name": f"{sname}/ERROR",
                            "us_per_call": 0.0,
                            "derived": f"{type(e).__name__}:{e}"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": [s for s, _ in suites],
                       "failures": failures, "rows": results}, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
