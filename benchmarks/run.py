# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV; ``--json PATH`` additionally writes the rows as a perf-trajectory
# artifact (e.g. BENCH_runtime.json) for CI comparison across PRs.
# Sub-suites: paper_sim (Reshape Ch.3 figures on the Tier-A simulator),
# runtime_bench (Amber Ch.2 + live-MoE on the real JAX runtime),
# maestro_bench (Ch.4 FRT/materialization), gauntlet (scenario-diverse
# SLO-graded load harness + autotune recovery).
#
# Each suite exposes a per-bench registry (``benches(smoke)`` -> list of
# (name, fn)) when its benches can run individually; ``--only`` filters on
# those names and ``--timeout`` arms a per-bench wall-clock guard (SIGALRM,
# main thread, POSIX) so one wedged bench turns into an ERROR row instead
# of hanging the whole run.
import argparse
import contextlib
import json
import signal
import sys
import threading


class BenchTimeout(Exception):
    pass


@contextlib.contextmanager
def _guard(seconds: int, name: str):
    """Per-bench wall-clock guard.  SIGALRM only works on the main thread
    of a POSIX process; anywhere else the guard degrades to a no-op rather
    than failing the run."""
    usable = (seconds > 0 and hasattr(signal, "SIGALRM")
              and threading.current_thread() is threading.main_thread())
    if not usable:
        yield
        return

    def _alarm(signum, frame):
        raise BenchTimeout(f"{name} exceeded {seconds}s wall-clock guard")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _suite_benches(sname, mod, smoke):
    """A suite's per-bench registry, falling back to one whole-suite entry
    for suites that don't expose ``benches``."""
    if hasattr(mod, "benches"):
        return mod.benches(smoke)
    run = (lambda: mod.run(smoke=True)) if smoke else mod.run
    return [(sname, run)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all", "sim", "runtime", "maestro",
                             "gauntlet"])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON perf artifact")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: only the fast A/B comparison benches of "
                         "the runtime suite; miniaturized gauntlet "
                         "scenarios")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run only benches whose registry name contains "
                         "this substring (e.g. one gauntlet scenario)")
    ap.add_argument("--timeout", type=int, default=900, metavar="SECONDS",
                    help="per-bench wall-clock guard; 0 disables")
    args = ap.parse_args()

    sys.path.insert(0, "src")
    suites = []
    if args.suite in ("all", "sim") and not args.smoke:
        from benchmarks import paper_sim
        suites.append(("sim", paper_sim))
    if args.suite in ("all", "runtime"):
        from benchmarks import runtime_bench
        suites.append(("runtime", runtime_bench))
    if args.suite in ("all", "maestro") and not args.smoke:
        from benchmarks import maestro_bench
        suites.append(("maestro", maestro_bench))
    if args.suite in ("all", "gauntlet"):
        from benchmarks import gauntlet
        suites.append(("gauntlet", gauntlet))

    print("name,us_per_call,derived")
    failures = 0
    results = []
    for sname, mod in suites:
        for bname, fn in _suite_benches(sname, mod, args.smoke):
            if args.only and args.only not in bname:
                continue
            try:
                with _guard(args.timeout, f"{sname}/{bname}"):
                    rows = fn()
            except (Exception, BenchTimeout) as e:  # pragma: no cover
                failures += 1
                print(f"{sname}/{bname}/ERROR,0,{type(e).__name__}:{e}",
                      flush=True)
                results.append({"suite": sname,
                                "name": f"{sname}/{bname}/ERROR",
                                "us_per_call": 0.0,
                                "derived": f"{type(e).__name__}:{e}"})
                continue
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
                results.append({"suite": sname, "name": name,
                                "us_per_call": round(us, 1),
                                "derived": derived})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": [s for s, _ in suites],
                       "failures": failures, "rows": results}, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
