# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV.  Sub-suites: paper_sim (Reshape Ch.3 figures on the Tier-A simulator),
# runtime_bench (Amber Ch.2 + live-MoE on the real JAX runtime),
# maestro_bench (Ch.4 FRT/materialization).
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all", "sim", "runtime", "maestro"])
    args = ap.parse_args()

    sys.path.insert(0, "src")
    suites = []
    if args.suite in ("all", "sim"):
        from benchmarks import paper_sim
        suites.append(("sim", paper_sim.run))
    if args.suite in ("all", "runtime"):
        from benchmarks import runtime_bench
        suites.append(("runtime", runtime_bench.run))
    if args.suite in ("all", "maestro"):
        from benchmarks import maestro_bench
        suites.append(("maestro", maestro_bench.run))

    print("name,us_per_call,derived")
    failures = 0
    for sname, fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{sname}/ERROR,0,{type(e).__name__}:{e}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
