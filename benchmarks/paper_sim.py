"""Benchmarks reproducing the Reshape chapter's figures on the Tier-A
pipelined simulator (paper §3.7).  Each returns CSV rows
(name, us_per_call, derived) where `derived` carries the figure's metric."""
from __future__ import annotations

import time

import numpy as np

from repro.core.adaptive import TauAdjuster
from repro.core.skew import SkewParams
from repro.core.strategies import (FlowJoinStrategy, FluxStrategy,
                                   NoMitigation, ReshapeStrategy)
from repro.core.transfer import PartitionLogic
from repro.core.worker import PipelinedSim
from repro.data.synthetic import tweets_like_rates

import math

KEYS = list(range(50))
RATES = tweets_like_rates(50)
EMIT_TICKS = 300          # finite input, as in the paper's bounded datasets


def _noisy(rates, t, amp=0.4):
    """Deterministic pseudo-noise so the estimator sees real variance."""
    return {k: r * (1.0 + amp * math.sin(0.7 * t + k)) for k, r in
            rates.items()}


def _mk(proc=5.0, rates=None, noise=0.0, emit_ticks=EMIT_TICKS, **kw):
    base = rates or RATES

    def f(t):
        if t >= emit_ticks:
            return {}
        return _noisy(base, t, noise) if noise else base
    return PipelinedSim(50, f, proc_rate=proc,
                        logic=PartitionLogic.modulo(KEYS, 50), **kw)


def _pair_lb(sim, skewed=6):
    """LB between the skewed worker and ITS helper (workers sharing key 6),
    falling back to the least-loaded worker when unmitigated (paper §3.7.4)."""
    arr = sim.arrived
    sharers = [w for w, _ in sim.logic.assignment[skewed] if w != skewed]
    if sharers:
        other = max(arr[w] for w in sharers)
    else:
        other = min(a for i, a in enumerate(arr) if i != skewed)
    return min(arr[skewed], other) / max(arr[skewed], other, 1.0)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def bench_result_ratio():
    """Fig 3.16/3.17: |observed - actual| CA:AZ ratio over time."""
    rows = []
    true_ratio = RATES[6] / RATES[4]
    for name, strat in [("none", NoMitigation()),
                        ("flux", FluxStrategy(SkewParams(eta=20, tau=20))),
                        ("flowjoin", FlowJoinStrategy()),
                        ("reshape", ReshapeStrategy(SkewParams(eta=20,
                                                               tau=20)))]:
        sim = _mk()
        t_hit = [None]

        def obs(s):
            r = s.processed_key[6] / max(s.processed_key[4], 1.0)
            if t_hit[0] is None and abs(r - true_ratio) / true_ratio < 0.25:
                t_hit[0] = s.tick_no
        _, us = _timed(lambda: sim.run(1800, strat, 5, observer=obs))
        final = sim.processed_key[6] / max(sim.processed_key[4], 1.0)
        rows.append((f"fig3.16_result_ratio/{name}", us,
                     f"ticks_to_representative={t_hit[0]};"
                     f"final_ratio={final:.2f};true={true_ratio:.2f}"))
    return rows


def bench_first_phase():
    """Fig 3.18/3.19: the first phase shows MORE representative results
    earlier — compare the observed/true ratio mid-stream (at emission end)
    and the time to reach the representative band."""
    rows = []
    true_ratio = RATES[6] / RATES[4]
    for name, fp in [("two_phase", True), ("second_only", False)]:
        sim = _mk()
        t_hit = [None]
        at_300 = [0.0]

        def obs(s):
            r = s.processed_key[6] / max(s.processed_key[4], 1.0)
            if s.tick_no == EMIT_TICKS:
                at_300[0] = r
            if t_hit[0] is None and abs(r - true_ratio) / true_ratio < 0.25:
                t_hit[0] = s.tick_no
        _, us = _timed(lambda: sim.run(
            1800, ReshapeStrategy(SkewParams(eta=20, tau=20), first_phase=fp),
            5, observer=obs))
        rows.append((f"fig3.18_first_phase/{name}", us,
                     f"ratio_at_emission_end={at_300[0]:.2f} (true "
                     f"{true_ratio:.2f});ticks_to_representative={t_hit[0]}"))
    return rows


def bench_heavy_hitter():
    """Fig 3.20: average load-balancing ratio per strategy."""
    rows = []
    for name, strat in [("flux", FluxStrategy(SkewParams(eta=20, tau=20))),
                        ("flowjoin_d2", FlowJoinStrategy(detect_window=2)),
                        ("flowjoin_d8", FlowJoinStrategy(detect_window=8)),
                        ("reshape", ReshapeStrategy(SkewParams(eta=20,
                                                               tau=20)))]:
        lbs = []
        sim = _mk()

        def obs(s):
            if s.tick_no % 10 == 0 and s.tick_no > 20:
                lbs.append(_pair_lb(s))
        _, us = _timed(lambda: sim.run(400, strat, 5, observer=obs))
        rows.append((f"fig3.20_heavy_hitter/{name}", us,
                     f"avg_lb_ratio={np.mean(lbs):.3f}"))
    return rows


def bench_control_delay():
    """Fig 3.21: LB ratio vs control-message delay."""
    rows = []
    for delay in (0, 5, 15, 30):
        sim = _mk(control_delay=delay)
        lbs = []

        def obs(s):
            if s.tick_no % 10 == 0 and s.tick_no > 20:
                lbs.append(_pair_lb(s))
        _, us = _timed(lambda: sim.run(
            400, ReshapeStrategy(SkewParams(eta=20, tau=20)), 5,
            observer=obs))
        rows.append((f"fig3.21_control_delay/{delay}t", us,
                     f"avg_lb_ratio={np.mean(lbs):.3f}"))
    return rows


def bench_adaptive_tau():
    """Fig 3.22: avg LB per mitigation iteration, fixed vs dynamic tau."""
    rows = []
    for tau in (2, 20, 400, 2000):
        for dyn in (False, True):
            adj = TauAdjuster(eps_l=12.0, eps_u=25.0, tau=tau,
                              increase_by=30) if dyn else None
            strat = ReshapeStrategy(SkewParams(eta=20, tau=tau),
                                    adaptive_tau=adj)
            sim = _mk(noise=0.2, emit_ticks=280)
            lbs = []

            def obs(s):
                if s.tick_no % 10 == 0 and s.tick_no > 20:
                    lbs.append(_pair_lb(s))
            _, us = _timed(lambda: sim.run(400, strat, 5, observer=obs))
            rows.append((f"fig3.22_adaptive_tau/tau{tau}_"
                         f"{'dyn' if dyn else 'fixed'}", us,
                         f"avg_lb={np.mean(lbs):.3f};"
                         f"migrations={strat.migrations};"
                         f"refreshes={strat.iterations}"))
    return rows


def bench_skew_levels():
    """Fig 3.23: LB under moderate vs high skew."""
    rows = []
    for name, hot in [("moderate", 6.0), ("high", 26.0)]:
        rates = {k: 1.0 for k in KEYS}
        rates[6] = hot
        sim = _mk(rates=rates)
        lbs = []

        def obs(s):
            if s.tick_no % 10 == 0 and s.tick_no > 20:
                lbs.append(_pair_lb(s))
        _, us = _timed(lambda: sim.run(
            400, ReshapeStrategy(SkewParams(eta=10, tau=10)), 5,
            observer=obs))
        rows.append((f"fig3.23_skew_levels/{name}", us,
                     f"avg_lb_ratio={np.mean(lbs):.3f}"))
    return rows


def bench_distribution_shift():
    """Fig 3.24: workload ratio tracking across a mid-stream shift."""
    rates_a = {k: 1.0 for k in KEYS}
    rates_a[0] = 20.0
    rates_b = {k: 1.0 for k in KEYS}
    rates_b[0] = 8.0
    rates_b[1] = 13.0
    rows = []
    for name, strat in [("flux", FluxStrategy(SkewParams(eta=15, tau=15))),
                        ("flowjoin", FlowJoinStrategy()),
                        ("reshape", ReshapeStrategy(SkewParams(eta=15,
                                                               tau=15)))]:
        sim = PipelinedSim(50, lambda t: rates_a if t < 150 else rates_b,
                           proc_rate=4.0,
                           logic=PartitionLogic.modulo(KEYS, 50))
        _, us = _timed(lambda: sim.run(400, strat, 5))
        spread = float(np.std(sim.arrived))
        rows.append((f"fig3.24_dist_shift/{name}", us,
                     f"arrival_spread={spread:.1f}"))
    return rows


def bench_multi_helper():
    """Fig 3.26: load reduction vs helper count w/ migration cost."""
    from repro.core.helpers import choose_helpers, lr_max
    rows = []
    for n_max in (1, 2, 4, 8, 16, 24):
        cands = [(i + 1, 0.02) for i in range(n_max)]
        t0 = time.perf_counter()
        chosen = choose_helpers(0.4, cands, 27e6, 27e6, 65000,
                                lambda n: 15 + 3.0 * n)
        us = (time.perf_counter() - t0) * 1e6
        fracs = [0.02] * len(chosen)
        lr_sel = lr_max(0.4, fracs, 27e6)
        rows.append((f"fig3.26_multi_helper/max{n_max}", us,
                     f"chosen={len(chosen)};lr_max={lr_sel:.3e}"))
    return rows


def bench_sort_reshape():
    """Table 3.2: Reshape on range-sort — LB + sortedness invariant."""
    from repro.core.state_migration import (RangeSortWorker,
                                            merged_sorted_output)
    rows = []
    rng = np.random.default_rng(0)
    n = 20_000
    # skewed totalprice-like distribution (lognormal)
    values = (rng.lognormal(3.0, 0.6, n) * 10).astype(int)
    t0 = time.perf_counter()
    workers = [RangeSortWorker(i) for i in range(4)]
    bounds = [30, 60, 120]                      # skewed ranges
    scopes = ["r0", "r1", "r2", "r3"]
    owner = {s: workers[i] for i, s in enumerate(scopes)}
    counts = [0, 0, 0, 0]
    for i, v in enumerate(values):
        si = sum(v > b for b in bounds)
        w = workers[si]
        # SBR: hot range r2 split 50/50 with helper worker 0
        if si == 2 and i % 2 == 0:
            w = workers[0]
        counts[w.wid] += 1
        w.process(scopes[si], int(v))
    for w in workers:
        w.on_end_marker(0, 1, owner)
    out = merged_sorted_output(workers, scopes)
    us = (time.perf_counter() - t0) * 1e6
    ok = out == sorted(values.tolist())
    lb = min(counts[0], counts[2]) / max(counts[0], counts[2])
    rows.append(("tbl3.2_sort_reshape", us,
                 f"sorted={ok};lb_ratio={lb:.2f};n={n}"))
    return rows


def run():
    rows = []
    for fn in (bench_result_ratio, bench_first_phase, bench_heavy_hitter,
               bench_control_delay, bench_adaptive_tau, bench_skew_levels,
               bench_distribution_shift, bench_multi_helper,
               bench_sort_reshape):
        rows.extend(fn())
    return rows
